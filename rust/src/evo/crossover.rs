//! One-point *messy* crossover (paper §4.2).
//!
//! "GEVO-ML begins with two randomly selected individuals, concatenates
//! the two lists of mutations (edits) in the patch representation;
//! shuffles the sequence; and then randomly selects a location to cut the
//! list back into two." The offspring are then re-applied to the original
//! program; about 80% of recombinations are valid (we regenerate that
//! statistic in `cargo bench --bench crossover_validity`).

use super::patch::{Edit, Individual};
use crate::util::rng::Rng;
use std::collections::BTreeSet;

/// Recombine two edit lists into two children (unvalidated).
pub fn messy_one_point(a: &Individual, b: &Individual, rng: &mut Rng) -> (Individual, Individual) {
    let mut pool: Vec<Edit> = a.edits.iter().chain(b.edits.iter()).copied().collect();
    rng.shuffle(&mut pool);
    let cut = if pool.is_empty() { 0 } else { rng.below(pool.len() + 1) };
    let (left, right) = pool.split_at(cut);
    (Individual::new(left.to_vec()), Individual::new(right.to_vec()))
}

/// [`messy_one_point`] with attribution protection: edits
/// [`crate::opt::minimize`] proved load-bearing stay pinned to their
/// originating child (prepended, in parent order) while only the
/// unprotected remainder is shuffled and cut. With an empty protected
/// set this is `messy_one_point` exactly — same children, same RNG
/// draws — which is why the scheduler can call it unconditionally only
/// when hints exist ([`super::operators::MessyCrossover`]).
pub fn messy_one_point_protected(
    a: &Individual,
    b: &Individual,
    rng: &mut Rng,
    protected: &BTreeSet<Edit>,
) -> (Individual, Individual) {
    let split = |ind: &Individual| -> (Vec<Edit>, Vec<Edit>) {
        ind.edits.iter().copied().partition(|e| protected.contains(e))
    };
    let (keep_a, rest_a) = split(a);
    let (keep_b, rest_b) = split(b);
    let mut pool: Vec<Edit> = rest_a.into_iter().chain(rest_b).collect();
    rng.shuffle(&mut pool);
    let cut = if pool.is_empty() { 0 } else { rng.below(pool.len() + 1) };
    let (left, right) = pool.split_at(cut);
    let mut c1 = keep_a;
    c1.extend_from_slice(left);
    let mut c2 = keep_b;
    c2.extend_from_slice(right);
    (Individual::new(c1), Individual::new(c2))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evo::patch::EditKind;
    use crate::ir::types::ValueId;

    fn ind(ids: &[u32]) -> Individual {
        Individual::new(
            ids.iter()
                .map(|&i| Edit {
                    kind: EditKind::Delete { target: ValueId(i) },
                    seed: i as u64,
                })
                .collect(),
        )
    }

    #[test]
    fn children_partition_the_pool() {
        let mut rng = Rng::new(1);
        let a = ind(&[1, 2, 3]);
        let b = ind(&[4, 5]);
        for _ in 0..50 {
            let (c, d) = messy_one_point(&a, &b, &mut rng);
            assert_eq!(c.edits.len() + d.edits.len(), 5);
            let mut all: Vec<u64> = c.edits.iter().chain(d.edits.iter()).map(|e| e.seed).collect();
            all.sort_unstable();
            assert_eq!(all, vec![1, 2, 3, 4, 5]);
        }
    }

    #[test]
    fn empty_parents_give_empty_children() {
        let mut rng = Rng::new(2);
        let (c, d) = messy_one_point(&Individual::original(), &Individual::original(), &mut rng);
        assert!(c.edits.is_empty() && d.edits.is_empty());
    }

    #[test]
    fn protected_edits_stay_with_their_parent() {
        let mut rng = Rng::new(4);
        let a = ind(&[1, 2, 3]);
        let b = ind(&[4, 5, 6]);
        let protected: BTreeSet<Edit> = [a.edits[0], b.edits[2]].into_iter().collect();
        for _ in 0..50 {
            let (c, d) = messy_one_point_protected(&a, &b, &mut rng, &protected);
            // still a partition of the pool
            let mut all: Vec<u64> =
                c.edits.iter().chain(d.edits.iter()).map(|e| e.seed).collect();
            all.sort_unstable();
            assert_eq!(all, vec![1, 2, 3, 4, 5, 6]);
            // protected edits pinned to their originating child
            assert_eq!(c.edits[0], a.edits[0], "a's protected edit leads child 1");
            assert_eq!(d.edits[0], b.edits[2], "b's protected edit leads child 2");
            assert!(!d.edits.contains(&a.edits[0]));
            assert!(!c.edits.contains(&b.edits[2]));
        }
    }

    #[test]
    fn empty_protected_set_is_plain_messy_one_point() {
        // Same children AND the same RNG stream afterwards.
        let a = ind(&[1, 2, 3]);
        let b = ind(&[4, 5]);
        for seed in 0..30u64 {
            let mut r1 = Rng::new(seed);
            let mut r2 = Rng::new(seed);
            let plain = messy_one_point(&a, &b, &mut r1);
            let prot = messy_one_point_protected(&a, &b, &mut r2, &BTreeSet::new());
            assert_eq!(plain.0.edits, prot.0.edits, "seed {seed}");
            assert_eq!(plain.1.edits, prot.1.edits, "seed {seed}");
            assert_eq!(r1.state(), r2.state(), "seed {seed}");
        }
    }

    #[test]
    fn cut_point_varies() {
        let mut rng = Rng::new(3);
        let a = ind(&[1, 2, 3, 4]);
        let b = ind(&[5, 6, 7, 8]);
        let mut lens = std::collections::BTreeSet::new();
        for _ in 0..100 {
            let (c, _) = messy_one_point(&a, &b, &mut rng);
            lens.insert(c.edits.len());
        }
        assert!(lens.len() > 3, "cut point should vary, saw {lens:?}");
    }
}
