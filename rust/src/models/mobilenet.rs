//! MobileNet-lite — the paper's model-*prediction* workload (Table 1,
//! §5, §6.1), scaled to interpreter-tractable size while keeping every
//! layer *type* of MobileNet: standard convolution, depthwise-separable
//! blocks (depthwise conv + pointwise conv), batch normalization after
//! every conv, global average pooling, and a fully-connected classifier.
//!
//! Weights are either loaded from the AOT artifacts (pretrained in JAX by
//! `python/compile/pretrain.py`) or generated from a seed (tests).
//!
//! §6.1 mutation targets are labeled: `bn{i}_gamma`, `fc_bias_add`,
//! `conv_last`, and [`key_mutations`] reconstructs the paper's three
//! epistatic MobileNet mutations for the mutation-analysis experiment.

use super::{batch_norm, bcast_row, relu, softmax};
use crate::ir::types::TType;
use crate::ir::{Graph, OpKind, ValueId};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use std::collections::BTreeMap;

/// Architecture hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct MobileNetSpec {
    pub batch: usize,
    /// Input is `side × side × 3` NHWC.
    pub side: usize,
    pub classes: usize,
    /// Base channel width (doubled down the stack).
    pub width: usize,
    /// Number of depthwise-separable blocks.
    pub blocks: usize,
}

impl Default for MobileNetSpec {
    fn default() -> Self {
        MobileNetSpec { batch: 8, side: 16, classes: 10, width: 8, blocks: 5 }
    }
}

/// Per-layer channel plan: (stride, out_channels) per separable block.
///
/// Channels double on stride-2 blocks and stay constant on stride-1
/// blocks — like real MobileNet, where stride-1 separable blocks are
/// shape-preserving. That redundancy is what lets the search bypass whole
/// blocks at small accuracy cost (the Fig. 4a trade-off).
fn plan(spec: &MobileNetSpec) -> Vec<(usize, usize)> {
    (0..spec.blocks)
        .map(|i| {
            let stride = if i % 2 == 0 { 2 } else { 1 };
            let ch = spec.width << (i / 2 + 1).min(3);
            (stride, ch)
        })
        .collect()
}

/// Named weight tensors for the whole network.
pub type Weights = BTreeMap<String, Tensor>;

/// Generate reproducible random weights (BN statistics set to identity-ish
/// values so the untrained net is numerically tame).
pub fn random_weights(spec: &MobileNetSpec, seed: u64) -> Weights {
    let mut rng = Rng::new(seed);
    let mut w = Weights::new();
    let add_bn = |w: &mut Weights, name: &str, c: usize, rng: &mut Rng| {
        w.insert(format!("{name}_gamma"), Tensor::rand_uniform(&[c], 0.8, 1.2, rng));
        w.insert(format!("{name}_beta"), Tensor::rand_uniform(&[c], -0.1, 0.1, rng));
        w.insert(format!("{name}_mean"), Tensor::rand_uniform(&[c], -0.1, 0.1, rng));
        w.insert(format!("{name}_var"), Tensor::rand_uniform(&[c], 0.5, 1.5, rng));
    };
    w.insert("conv1_w".into(), super::glorot(&[3, 3, 3, spec.width], &mut rng));
    add_bn(&mut w, "bn1", spec.width, &mut rng);
    let mut cin = spec.width;
    for (i, (_, cout)) in plan(spec).iter().enumerate() {
        w.insert(format!("dw{i}_w"), super::glorot(&[3, 3, cin], &mut rng));
        add_bn(&mut w, &format!("bn_dw{i}"), cin, &mut rng);
        w.insert(format!("pw{i}_w"), super::glorot(&[1, 1, cin, *cout], &mut rng));
        add_bn(&mut w, &format!("bn_pw{i}"), *cout, &mut rng);
        cin = *cout;
    }
    w.insert("fc_w".into(), super::glorot(&[cin, spec.classes], &mut rng));
    w.insert("fc_b".into(), Tensor::zeros(&[spec.classes]));
    w
}

fn take(w: &Weights, key: &str) -> Tensor {
    w.get(key)
        .unwrap_or_else(|| panic!("missing weight '{key}'"))
        .clone()
}

/// Build the prediction graph: parameter `x [B, side, side, 3]` →
/// softmax probabilities `[B, classes]`. All weights are embedded
/// constants (the mutation search space, as in the paper where GEVO-ML
/// mutates the whole lowered model).
pub fn predict_graph(spec: &MobileNetSpec, w: &Weights) -> Graph {
    let mut g = Graph::new("mobilenet_predict");
    let x = g.param(TType::of(&[spec.batch, spec.side, spec.side, 3]));

    // stem: conv 3x3 stride 1 + BN + relu
    let cw = g.constant(take(w, "conv1_w"));
    let c1 = g
        .push_labeled(OpKind::Conv2d { stride: 1, same: true }, &[x, cw], "conv1")
        .unwrap();
    let b1 = batch_norm(
        &mut g,
        c1,
        take(w, "bn1_gamma"),
        take(w, "bn1_beta"),
        take(w, "bn1_mean"),
        take(w, "bn1_var"),
        "bn1",
    );
    let mut h = relu(&mut g, b1);

    // depthwise-separable blocks
    let p = plan(spec);
    for (i, (stride, _cout)) in p.iter().enumerate() {
        let dw = g.constant(take(w, &format!("dw{i}_w")));
        let dconv = g
            .push_labeled(
                OpKind::DepthwiseConv2d { stride: *stride, same: true },
                &[h, dw],
                &format!("dwconv{i}"),
            )
            .unwrap();
        let dbn = batch_norm(
            &mut g,
            dconv,
            take(w, &format!("bn_dw{i}_gamma")),
            take(w, &format!("bn_dw{i}_beta")),
            take(w, &format!("bn_dw{i}_mean")),
            take(w, &format!("bn_dw{i}_var")),
            &format!("bn_dw{i}"),
        );
        let dact = relu(&mut g, dbn);
        let pw = g.constant(take(w, &format!("pw{i}_w")));
        let label = if i + 1 == p.len() { "conv_last".to_string() } else { format!("pwconv{i}") };
        let pconv = g
            .push_labeled(OpKind::Conv2d { stride: 1, same: true }, &[dact, pw], &label)
            .unwrap();
        let pbn = batch_norm(
            &mut g,
            pconv,
            take(w, &format!("bn_pw{i}_gamma")),
            take(w, &format!("bn_pw{i}_beta")),
            take(w, &format!("bn_pw{i}_mean")),
            take(w, &format!("bn_pw{i}_var")),
            &format!("bn_pw{i}"),
        );
        h = relu(&mut g, pbn);
    }

    // head: global average pool → fc → softmax
    let pooled = g.push_labeled(OpKind::GlobalAvgPool, &[h], "avgpool").unwrap();
    let fcw = g.constant(take(w, "fc_w"));
    let fc = g.push_labeled(OpKind::Dot, &[pooled, fcw], "fc").unwrap();
    let fcb = g.constant(take(w, "fc_b"));
    let fcbb = bcast_row(&mut g, fcb, spec.batch, spec.classes);
    let logits = g.push_labeled(OpKind::Add, &[fc, fcbb], "fc_bias_add").unwrap();
    let probs = softmax(&mut g, logits);
    g.set_outputs(&[probs]);
    g
}

/// The three key §6.1 mutations, reconstructed as direct graph edits so
/// the mutation-analysis experiment can apply them singly and jointly:
///
/// 1. `bn_gamma_swap` — replace the γ of the *last* pointwise BN with the
///    γ of the previous block's pointwise BN (resized if channel counts
///    differ, per §4.1);
/// 2. `drop_fc_bias` — delete the classifier's bias add;
/// 3. `drop_last_conv` — delete the last pointwise convolution.
///
/// Returns the number of graph edits applied.
pub fn key_mutations(g: &mut Graph, which: &[KeyMutation]) -> usize {
    let mut applied = 0;
    for m in which {
        match m {
            KeyMutation::BnGammaSwap => {
                let last = g.len();
                // find γ constants of the two most recent pointwise BNs
                let gammas: Vec<ValueId> = g
                    .insts()
                    .iter()
                    .filter(|i| {
                        i.label
                            .as_deref()
                            .map(|l| l.starts_with("bn_pw") && l.ends_with("_gamma"))
                            .unwrap_or(false)
                    })
                    .map(|i| i.id)
                    .collect();
                if gammas.len() >= 2 {
                    let donor = gammas[gammas.len() - 2];
                    let victim = gammas[gammas.len() - 1];
                    let want = g.ty(victim).unwrap().clone();
                    // adapt donor to victim's type, then rewire all uses
                    let vpos = g.index_of(victim).unwrap();
                    let insert_at = g
                        .index_of(donor)
                        .unwrap()
                        .max(vpos)
                        + 1;
                    if let Ok((adapted, _, _)) =
                        crate::ir::resize::resize_chain(g, insert_at, donor, &want)
                    {
                        let uses = g.uses_of(victim);
                        let mut ok = false;
                        for u in uses {
                            if let crate::ir::graph::Use::Arg { pos, slot } = u {
                                // only rewire uses after the adapter
                                if pos > g.index_of(adapted).unwrap()
                                    && g.replace_arg(pos, slot, adapted).is_ok()
                                {
                                    ok = true;
                                }
                            }
                        }
                        if ok {
                            applied += 1;
                        }
                    }
                }
                let _ = last;
            }
            KeyMutation::DropFcBias => {
                if let Some(id) = g.find_label("fc_bias_add") {
                    // bypass: rewire uses of the add to its first operand
                    let src = g.inst(id).unwrap().args[0];
                    let uses = g.uses_of(id);
                    let mut ok = true;
                    for u in uses {
                        match u {
                            crate::ir::graph::Use::Arg { pos, slot } => {
                                ok &= g.replace_arg(pos, slot, src).is_ok();
                            }
                            crate::ir::graph::Use::Output { slot } => {
                                ok &= g.replace_output(slot, src).is_ok();
                            }
                        }
                    }
                    if ok {
                        let pos = g.index_of(id).unwrap();
                        g.remove_at(pos);
                        applied += 1;
                    }
                }
            }
            KeyMutation::DropLastConv => {
                if let Some(id) = g.find_label("conv_last") {
                    // bypass the conv: adapt its input to its output type
                    let src = g.inst(id).unwrap().args[0];
                    let want = g.ty(id).unwrap().clone();
                    let pos = g.index_of(id).unwrap();
                    if let Ok((adapted, _, inserted)) =
                        crate::ir::resize::resize_chain(g, pos, src, &want)
                    {
                        let id_pos = pos + inserted;
                        debug_assert_eq!(g.inst_at(id_pos).id, id);
                        let uses = g.uses_of(id);
                        let mut ok = true;
                        for u in uses {
                            match u {
                                crate::ir::graph::Use::Arg { pos, slot } => {
                                    ok &= g.replace_arg(pos, slot, adapted).is_ok();
                                }
                                crate::ir::graph::Use::Output { slot } => {
                                    ok &= g.replace_output(slot, adapted).is_ok();
                                }
                            }
                        }
                        if ok {
                            let pos = g.index_of(id).unwrap();
                            g.remove_at(pos);
                            applied += 1;
                        }
                    }
                }
            }
        }
    }
    g.eliminate_dead_code();
    applied
}

/// Which §6.1 mutation to apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KeyMutation {
    BnGammaSwap,
    DropFcBias,
    DropLastConv,
}

/// Classify a dataset; returns accuracy. Partial batches dropped.
pub fn accuracy_on(g: &Graph, spec: &MobileNetSpec, data: &crate::data::Dataset) -> f64 {
    let mut correct = 0usize;
    let mut total = 0usize;
    for (bi, (x, _)) in data.batches(spec.batch).iter().enumerate() {
        let Ok(out) = crate::interp::eval(g, &[x.clone()]) else { return 0.0 };
        let preds = crate::tensor::ops::argmax_last(&out[0]);
        for (row, &p) in preds.data().iter().enumerate() {
            if p as usize == data.labels[bi * spec.batch + row] {
                correct += 1;
            }
            total += 1;
        }
    }
    correct as f64 / total.max(1) as f64
}

/// Layer census in the paper's Table 1 vocabulary.
pub fn table1_census(g: &Graph) -> Vec<(String, usize)> {
    let c = g.census();
    let bn = g
        .insts()
        .iter()
        .filter(|i| i.label.as_deref().map(|l| l.ends_with("_out")).unwrap_or(false))
        .count();
    vec![
        ("Depthwise-Convolution".into(), *c.get("depthwise_convolution").unwrap_or(&0)),
        ("Standard-Convolution".into(), *c.get("convolution").unwrap_or(&0)),
        ("Batch Norm.".into(), bn),
        ("Average Pool".into(), *c.get("global_avg_pool").unwrap_or(&0)),
        ("Fully-connected Layer".into(), *c.get("dot").unwrap_or(&0)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::patterns;

    fn spec() -> MobileNetSpec {
        MobileNetSpec { batch: 4, side: 16, classes: 10, width: 4, blocks: 3 }
    }

    #[test]
    fn builds_and_verifies() {
        let s = spec();
        let w = random_weights(&s, 1);
        let g = predict_graph(&s, &w);
        crate::ir::verify::verify(&g).unwrap();
        assert_eq!(g.output_types()[0], TType::of(&[4, 10]));
    }

    #[test]
    fn executes_and_rows_sum_to_one() {
        let s = spec();
        let w = random_weights(&s, 2);
        let g = predict_graph(&s, &w);
        let data = patterns::generate(8, s.side, 3);
        let (x, _) = data.batch(&[0, 1, 2, 3]);
        let out = crate::interp::eval(&g, &[x]).unwrap();
        for r in 0..4 {
            let sum: f32 = (0..10).map(|c| out[0].at(&[r, c])).sum();
            assert!((sum - 1.0).abs() < 1e-4, "row {r} sums {sum}");
        }
    }

    #[test]
    fn census_has_all_layer_types() {
        let s = spec();
        let w = random_weights(&s, 1);
        let g = predict_graph(&s, &w);
        let census = table1_census(&g);
        let m: std::collections::BTreeMap<_, _> = census.into_iter().collect();
        assert_eq!(m["Depthwise-Convolution"], 3);
        assert_eq!(m["Standard-Convolution"], 4); // stem + 3 pointwise
        assert_eq!(m["Batch Norm."], 7); // stem + 2 per block
        assert_eq!(m["Average Pool"], 1);
        assert_eq!(m["Fully-connected Layer"], 1);
    }

    #[test]
    fn key_mutations_apply_and_graph_stays_valid() {
        let s = spec();
        let w = random_weights(&s, 4);
        for muts in [
            vec![KeyMutation::BnGammaSwap],
            vec![KeyMutation::DropFcBias],
            vec![KeyMutation::DropLastConv],
            vec![KeyMutation::BnGammaSwap, KeyMutation::DropFcBias, KeyMutation::DropLastConv],
        ] {
            let mut g = predict_graph(&s, &w);
            let n = key_mutations(&mut g, &muts);
            assert_eq!(n, muts.len(), "all mutations must apply: {muts:?}");
            crate::ir::verify::verify(&g).unwrap_or_else(|e| panic!("{muts:?}: {e}"));
            // still executes
            let data = patterns::generate(4, s.side, 5);
            let (x, _) = data.batch(&[0, 1, 2, 3]);
            let out = crate::interp::eval(&g, &[x]).unwrap();
            assert_eq!(out[0].dims(), &[4, 10]);
        }
    }

    #[test]
    fn drop_last_conv_reduces_flops() {
        let s = spec();
        let w = random_weights(&s, 4);
        let g0 = predict_graph(&s, &w);
        let mut g1 = predict_graph(&s, &w);
        key_mutations(&mut g1, &[KeyMutation::DropLastConv]);
        assert!(
            g1.total_flops() < g0.total_flops(),
            "dropping a conv must reduce FLOPs"
        );
    }
}
