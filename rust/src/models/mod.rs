//! IR builders for the paper's two evaluation workloads (Table 1):
//!
//! * [`twofc`] — the 2fcNet training workload: a two-layer fully-connected
//!   network with an in-graph SGD step (forward + backward + update),
//!   op-for-op in the shape of the paper's Fig. 5 listing.
//! * [`mobilenet`] — the MobileNet prediction workload: a depthwise-
//!   separable CNN (conv/BN/relu blocks, global average pool, classifier),
//!   scaled to interpreter-tractable size (MobileNet-lite; DESIGN.md §3).
//!
//! Builders label the instructions that matter to the paper's mutation
//! analysis (`lr`, `grad_scale`, `bn{i}_gamma`, `fc_bias_add`, …) so
//! §6.1/§6.2 experiments can target them.

pub mod twofc;
pub mod mobilenet;

use crate::ir::types::TType;
use crate::ir::{Graph, OpKind, ValueId};

/// Helper: broadcast a `[c]` vector constant across `[rows, c]`.
pub(crate) fn bcast_row(g: &mut Graph, v: ValueId, rows: usize, c: usize) -> ValueId {
    g.push(OpKind::Broadcast { dims: vec![rows, c], mapping: vec![1] }, &[v])
        .expect("bcast_row")
}

/// Helper: broadcast a scalar across an arbitrary shape.
pub(crate) fn bcast_scalar(g: &mut Graph, v: ValueId, dims: &[usize]) -> ValueId {
    g.push(OpKind::Broadcast { dims: dims.to_vec(), mapping: vec![] }, &[v])
        .expect("bcast_scalar")
}

/// Helper: `relu(x) = maximum(x, broadcast(0))`, as in the paper's Fig. 1.
pub(crate) fn relu(g: &mut Graph, x: ValueId) -> ValueId {
    let dims = g.ty(x).unwrap().dims.clone();
    let zero = g.constant_scalar(0.0);
    let zb = bcast_scalar(g, zero, &dims);
    g.push(OpKind::Maximum, &[x, zb]).expect("relu")
}

/// Helper: row-softmax of `[rows, c]`, the Fig. 1 tail (max-subtract for
/// stability, exp, sum, divide).
pub(crate) fn softmax(g: &mut Graph, x: ValueId) -> ValueId {
    let dims = g.ty(x).unwrap().dims.clone();
    let (rows, c) = (dims[0], dims[1]);
    let m = g
        .push(OpKind::Reduce { dims: vec![1], kind: crate::ir::ReduceKind::Max }, &[x])
        .unwrap();
    let mb = g
        .push(OpKind::Broadcast { dims: vec![rows, c], mapping: vec![0] }, &[m])
        .unwrap();
    let s = g.push(OpKind::Subtract, &[x, mb]).unwrap();
    let e = g.push(OpKind::Exponential, &[s]).unwrap();
    let su = g
        .push(OpKind::Reduce { dims: vec![1], kind: crate::ir::ReduceKind::Sum }, &[e])
        .unwrap();
    let sb = g
        .push(OpKind::Broadcast { dims: vec![rows, c], mapping: vec![0] }, &[su])
        .unwrap();
    g.push(OpKind::Divide, &[e, sb]).unwrap()
}

/// Glorot-uniform initial weights, reproducible by seed.
pub(crate) fn glorot(
    dims: &[usize],
    rng: &mut crate::util::rng::Rng,
) -> crate::tensor::Tensor {
    let fan: usize = dims.iter().sum::<usize>().max(1);
    let limit = (6.0f32 / fan as f32).sqrt();
    crate::tensor::Tensor::rand_uniform(dims, -limit, limit, rng)
}

/// Batch-norm (inference form) on `[n,h,w,c]` with per-channel constants:
/// `γ·(x−μ)/√(σ²+ε) + β`. Labels the γ constant so §6.1's "replace the γ
/// value in one BN layer" mutation can target it.
#[allow(clippy::too_many_arguments)]
pub(crate) fn batch_norm(
    g: &mut Graph,
    x: ValueId,
    gamma: crate::tensor::Tensor,
    beta: crate::tensor::Tensor,
    mean: crate::tensor::Tensor,
    var: crate::tensor::Tensor,
    name: &str,
) -> ValueId {
    let dims = g.ty(x).unwrap().dims.clone();
    let c = *dims.last().unwrap();
    let map = vec![dims.len() - 1];
    let ga = g.constant(gamma);
    g.inst_mut(ga).unwrap().label = Some(format!("{name}_gamma"));
    let be = g.constant(beta);
    let mu = g.constant(mean);
    let va = g.constant(var);
    let eps = g.constant(crate::tensor::Tensor::full(&[c], 1e-5));
    let vpe = g.push(OpKind::Add, &[va, eps]).unwrap();
    let inv = g.push(OpKind::Rsqrt, &[vpe]).unwrap();
    let scale = g.push(OpKind::Multiply, &[ga, inv]).unwrap(); // γ/√(σ²+ε)  [c]
    let mb = g
        .push(OpKind::Broadcast { dims: dims.clone(), mapping: map.clone() }, &[mu])
        .unwrap();
    let xm = g.push(OpKind::Subtract, &[x, mb]).unwrap();
    let sb = g
        .push(OpKind::Broadcast { dims: dims.clone(), mapping: map.clone() }, &[scale])
        .unwrap();
    let xs = g.push(OpKind::Multiply, &[xm, sb]).unwrap();
    let bb = g
        .push(OpKind::Broadcast { dims, mapping: map }, &[be])
        .unwrap();
    g.push_labeled(OpKind::Add, &[xs, bb], &format!("{name}_out")).unwrap()
}

/// Accuracy of logits `[n, classes]` against labels.
pub fn accuracy(logits: &crate::tensor::Tensor, labels: &[usize]) -> f64 {
    let preds = crate::tensor::ops::argmax_last(logits);
    let correct = preds
        .data()
        .iter()
        .zip(labels.iter())
        .filter(|(&p, &l)| p as usize == l)
        .count();
    correct as f64 / labels.len().max(1) as f64
}

/// Checked type accessor used by workloads.
pub fn param_type(g: &Graph, index: usize) -> TType {
    g.param_types()[index].clone()
}
