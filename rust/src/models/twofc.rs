//! 2fcNet — the paper's model-*training* workload (Table 1, §5, §6.2).
//!
//! Two fully-connected layers trained with mini-batch SGD. The training
//! step is a single IR graph containing forward pass, softmax-cross-
//! entropy gradient, back-propagation, and the weight update — so GEVO-ML
//! "is able to freely optimize both model forward pass and
//! back-propagation pass" (§5). The Fig. 5 structure is reproduced
//! op-for-op in the gradient path, including the labeled mutation
//! targets: `grad_scale` (the `0.03125 = 1/32` constant of Fig. 5 line 7)
//! and `lr` (the learning rate applied at line 15).

use super::{bcast_row, bcast_scalar, glorot, relu, softmax};
use crate::ir::types::TType;
use crate::ir::{Graph, OpKind, ReduceKind, ValueId};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Model hyper-parameters. Paper values: 784-input MNIST, batch 32,
/// lr 0.01. The experiment default shrinks the input to 14×14 = 196 and
/// the hidden layer to 32 to keep thousands of fitness evaluations
/// tractable on the interpreter (DESIGN.md §3); `full_size()` restores
/// the paper dimensions.
#[derive(Debug, Clone, Copy)]
pub struct TwoFcSpec {
    pub batch: usize,
    pub input: usize,
    pub hidden: usize,
    pub classes: usize,
    pub lr: f32,
}

impl Default for TwoFcSpec {
    fn default() -> Self {
        TwoFcSpec { batch: 32, input: 196, hidden: 32, classes: 10, lr: 0.01 }
    }
}

impl TwoFcSpec {
    /// The paper's dimensions (MNIST 28×28, hidden 128).
    pub fn full_size() -> Self {
        TwoFcSpec { batch: 32, input: 784, hidden: 128, classes: 10, lr: 0.01 }
    }

    /// Image side length (input is a flattened square image).
    pub fn side(&self) -> usize {
        (self.input as f64).sqrt() as usize
    }
}

/// The model's weights (also the training state threaded through steps).
#[derive(Debug, Clone)]
pub struct TwoFcWeights {
    pub w1: Tensor,
    pub b1: Tensor,
    pub w2: Tensor,
    pub b2: Tensor,
}

impl TwoFcWeights {
    /// Reproducible Glorot init.
    pub fn init(spec: &TwoFcSpec, seed: u64) -> TwoFcWeights {
        let mut rng = Rng::new(seed);
        TwoFcWeights {
            w1: glorot(&[spec.input, spec.hidden], &mut rng),
            b1: Tensor::zeros(&[spec.hidden]),
            w2: glorot(&[spec.hidden, spec.classes], &mut rng),
            b2: Tensor::zeros(&[spec.classes]),
        }
    }

    pub fn as_vec(&self) -> Vec<Tensor> {
        vec![self.w1.clone(), self.b1.clone(), self.w2.clone(), self.b2.clone()]
    }

    pub fn from_slice(ts: &[Tensor]) -> TwoFcWeights {
        TwoFcWeights {
            w1: ts[0].clone(),
            b1: ts[1].clone(),
            w2: ts[2].clone(),
            b2: ts[3].clone(),
        }
    }
}

/// Forward-pass graph for prediction/accuracy measurement.
///
/// Parameters: `x [B,in], w1, b1, w2, b2`; output: softmax probabilities
/// `[B, classes]` (the Fig. 1 program).
pub fn predict_graph(spec: &TwoFcSpec) -> Graph {
    let mut g = Graph::new("twofc_predict");
    let x = g.param(TType::of(&[spec.batch, spec.input]));
    let w1 = g.param(TType::of(&[spec.input, spec.hidden]));
    let b1 = g.param(TType::of(&[spec.hidden]));
    let w2 = g.param(TType::of(&[spec.hidden, spec.classes]));
    let b2 = g.param(TType::of(&[spec.classes]));
    let p = forward(&mut g, spec, x, w1, b1, w2, b2);
    g.set_outputs(&[p.probs]);
    g
}

struct Forward {
    z1: ValueId,
    a1: ValueId,
    probs: ValueId,
}

fn forward(
    g: &mut Graph,
    spec: &TwoFcSpec,
    x: ValueId,
    w1: ValueId,
    b1: ValueId,
    w2: ValueId,
    b2: ValueId,
) -> Forward {
    let (b, h, c) = (spec.batch, spec.hidden, spec.classes);
    let d1 = g.push_labeled(OpKind::Dot, &[x, w1], "dense1").unwrap();
    let b1b = bcast_row(g, b1, b, h);
    let z1 = g.push(OpKind::Add, &[d1, b1b]).unwrap();
    let a1 = relu(g, z1);
    let d2 = g.push_labeled(OpKind::Dot, &[a1, w2], "dense2").unwrap();
    let b2b = bcast_row(g, b2, b, c);
    let z2 = g.push_labeled(OpKind::Add, &[d2, b2b], "logits").unwrap();
    let probs = softmax(g, z2);
    Forward { z1, a1, probs }
}

/// One SGD training step as a single graph (the Fig. 5 program).
///
/// Parameters: `x [B,in], y [B,classes] (one-hot), w1, b1, w2, b2`.
/// Outputs: `new_w1, new_b1, new_w2, new_b2, mean_loss`.
///
/// Mutation-relevant labels:
/// * `grad_scale` — the `1/B` constant (Fig. 5's `0.03125`), applied
///   elementwise to the gradient exactly as in line 7–10 of the figure;
/// * `lr` — the learning-rate constant applied to every update.
pub fn train_step_graph(spec: &TwoFcSpec) -> Graph {
    let (bsz, inp, h, c) = (spec.batch, spec.input, spec.hidden, spec.classes);
    let mut g = Graph::new("twofc_train_step");
    let x = g.param(TType::of(&[bsz, inp]));
    let y = g.param(TType::of(&[bsz, c]));
    let w1 = g.param(TType::of(&[inp, h]));
    let b1 = g.param(TType::of(&[h]));
    let w2 = g.param(TType::of(&[h, c]));
    let b2 = g.param(TType::of(&[c]));

    // ---- forward (Fig. 5 lines 1-5) --------------------------------------
    let f = forward(&mut g, spec, x, w1, b1, w2, b2);

    // ---- loss: mean cross-entropy (reported, not differentiated) ---------
    let logp = g.push(OpKind::Log, &[f.probs]).unwrap();
    let ylogp = g.push(OpKind::Multiply, &[y, logp]).unwrap();
    let per = g
        .push(OpKind::Reduce { dims: vec![0, 1], kind: ReduceKind::Sum }, &[ylogp])
        .unwrap();
    let negsum = g.push(OpKind::Negate, &[per]).unwrap();
    let inv_b = g.constant_scalar(1.0 / bsz as f32);
    let loss = g.push_labeled(OpKind::Multiply, &[negsum, inv_b], "mean_loss").unwrap();

    // ---- gradient (Fig. 5 lines 6-14) -------------------------------------
    // %62 = subtract(probs, label)
    let d2raw = g.push_labeled(OpKind::Subtract, &[f.probs, y], "grad_raw").unwrap();
    // %63 = multiply(%62, 0.03125)  — `grad_scale` is THE §6.2 target
    let gs = g.constant(Tensor::full(&[bsz, c], 1.0 / bsz as f32));
    g.inst_mut(gs).unwrap().label = Some("grad_scale".into());
    let d2 = g.push_labeled(OpKind::Multiply, &[d2raw, gs], "grad_scaled").unwrap();
    // dw2 = a1ᵀ · d2 ; db2 = reduce over batch (Fig. 5 lines 11-14)
    let a1t = g.push(OpKind::Transpose { perm: vec![1, 0] }, &[f.a1]).unwrap();
    let dw2 = g.push(OpKind::Dot, &[a1t, d2]).unwrap();
    let db2 = g
        .push(OpKind::Reduce { dims: vec![0], kind: ReduceKind::Sum }, &[d2])
        .unwrap();
    // backprop through layer 1
    let w2t = g.push(OpKind::Transpose { perm: vec![1, 0] }, &[w2]).unwrap();
    let da1 = g.push(OpKind::Dot, &[d2, w2t]).unwrap();
    let zero = g.constant_scalar(0.0);
    let zb = bcast_scalar(&mut g, zero, &[bsz, h]);
    let mask = g.push(OpKind::CompareGt, &[f.z1, zb]).unwrap(); // relu'
    let dz1 = g.push(OpKind::Multiply, &[da1, mask]).unwrap();
    let xt = g.push(OpKind::Transpose { perm: vec![1, 0] }, &[x]).unwrap();
    let dw1 = g.push(OpKind::Dot, &[xt, dz1]).unwrap();
    let db1 = g
        .push(OpKind::Reduce { dims: vec![0], kind: ReduceKind::Sum }, &[dz1])
        .unwrap();

    // ---- update (Fig. 5 lines 15-18): w ← w − lr·dw ------------------------
    let lr = g.constant_scalar(spec.lr);
    g.inst_mut(lr).unwrap().label = Some("lr".into());
    let upd = |g: &mut Graph, w: ValueId, dw: ValueId, name: &str| {
        let dims = g.ty(w).unwrap().dims.clone();
        let lrb = bcast_scalar(g, lr, &dims);
        let step = g.push(OpKind::Multiply, &[dw, lrb]).unwrap();
        g.push_labeled(OpKind::Subtract, &[w, step], name).unwrap()
    };
    let nw1 = upd(&mut g, w1, dw1, "new_w1");
    let nb1 = upd(&mut g, b1, db1, "new_b1");
    let nw2 = upd(&mut g, w2, dw2, "new_w2");
    let nb2 = upd(&mut g, b2, db2, "new_b2");

    g.set_outputs(&[nw1, nb1, nw2, nb2, loss]);
    g
}

/// Reconstruct the paper's §6.2 / Fig. 5 mutation as a graph edit:
/// GEVO-ML copied a `broadcast`, connected the *labels* tensor through a
/// pad/slice repair chain, and used the result to replace the `0.03125`
/// gradient-scale operand. After the repair the replacing tensor is
/// "filled mostly by value 1", so the gradient is effectively unscaled —
/// ≈ B× larger, the same effect as raising the learning rate.
///
/// Here: pad the one-hot labels `[B,C]` on the high edge of dim 1 by `C`
/// (pad value 1.0, per §4.1), slice columns `C..2C` (all 1s), and swap
/// that in for the `grad_scale` constant.
pub fn apply_fig5_gradient_mutation(g: &mut Graph) -> Result<(), crate::ir::IrError> {
    use crate::ir::graph::Use;
    let gs = g
        .find_label("grad_scale")
        .ok_or_else(|| crate::ir::IrError::Graph("no grad_scale label".into()))?;
    let uses = g.uses_of(gs);
    let Some(&Use::Arg { pos, slot }) = uses.first() else {
        return Err(crate::ir::IrError::Graph("grad_scale unused".into()));
    };
    // the labels parameter is entry index 1
    let y = g
        .insts()
        .iter()
        .find(|i| matches!(i.kind, OpKind::Parameter { index: 1 }))
        .map(|i| i.id)
        .ok_or_else(|| crate::ir::IrError::Graph("no labels parameter".into()))?;
    let dims = g.ty(y).unwrap().dims.clone(); // [B, C]
    let (_b, c) = (dims[0], dims[1]);
    // %R1 = pad(%label, 1) : [B,C] -> [B,2C]
    let padded = g.insert_at(
        pos,
        OpKind::Pad { low: vec![0, 0], high: vec![0, c], value: 1.0 },
        &[y],
    )?;
    // %R2/%I1 = slice columns C..2C (all pad values = 1)
    let ones = g.insert_at(
        pos + 1,
        OpKind::Slice { starts: vec![0, c], limits: vec![dims[0], 2 * c] },
        &[padded],
    )?;
    g.replace_arg(pos + 2, slot, ones)?;
    g.eliminate_dead_code();
    crate::ir::verify::verify(g)
}

/// Run `steps` of SGD with the given (possibly mutated) train-step graph
/// over pre-built batches; returns final weights and last loss, or `None`
/// if the graph fails to execute or produces non-finite state (§4.3's
/// "individuals execute successfully" requirement).
///
/// Interpreter-backed reference path; the fitness loop uses
/// [`run_training_prog`] over a compiled [`crate::exec::Program`], which
/// is bit-identical.
pub fn run_training(
    step: &Graph,
    init: &TwoFcWeights,
    batches: &[(Tensor, Tensor)],
    epochs: usize,
) -> Option<(TwoFcWeights, f64)> {
    let mut w = init.clone();
    let mut last_loss = f64::NAN;
    for _ in 0..epochs {
        for (x, y) in batches {
            let inputs = vec![
                x.clone(),
                y.clone(),
                w.w1.clone(),
                w.b1.clone(),
                w.w2.clone(),
                w.b2.clone(),
            ];
            let out = crate::interp::eval(step, &inputs).ok()?;
            if out.iter().take(4).any(|t| t.has_non_finite()) {
                return None;
            }
            w = TwoFcWeights::from_slice(&out[0..4]);
            last_loss = out[4].item() as f64;
        }
    }
    if !last_loss.is_finite() {
        return None;
    }
    Some((w, last_loss))
}

/// [`run_training`] through a compiled train-step program: the lowering is
/// amortized across `epochs × batches` executions, scratch buffers are
/// reused between steps, and inputs are passed by reference (no defensive
/// clones of the weight state).
pub fn run_training_prog(
    step: &crate::exec::Program,
    init: &TwoFcWeights,
    batches: &[(Tensor, Tensor)],
    epochs: usize,
) -> Option<(TwoFcWeights, f64)> {
    run_training_prog_profiled(step, init, batches, epochs, None)
}

/// [`run_training_prog`] with per-kernel timings folded into `sink` when
/// one is supplied (`--profile`). The profiled path runs the same steps
/// in the same order — outputs are bit-identical either way.
pub fn run_training_prog_profiled(
    step: &crate::exec::Program,
    init: &TwoFcWeights,
    batches: &[(Tensor, Tensor)],
    epochs: usize,
    mut sink: Option<&mut crate::telemetry::ProfileSink>,
) -> Option<(TwoFcWeights, f64)> {
    let mut w = init.clone();
    let mut last_loss = f64::NAN;
    let mut scratch = crate::exec::Scratch::new();
    for _ in 0..epochs {
        for (x, y) in batches {
            let inputs = [x, y, &w.w1, &w.b1, &w.w2, &w.b2];
            let mut out = match sink.as_deref_mut() {
                Some(s) => step.run_refs_profiled(&inputs, &mut scratch, s).ok()?,
                None => step.run_refs(&inputs, &mut scratch).ok()?,
            };
            if out.iter().take(4).any(|t| t.has_non_finite()) {
                return None;
            }
            last_loss = out[4].item() as f64;
            let b2 = out.swap_remove(3);
            let w2 = out.swap_remove(2);
            let b1 = out.swap_remove(1);
            let w1 = out.swap_remove(0);
            w = TwoFcWeights { w1, b1, w2, b2 };
        }
    }
    if !last_loss.is_finite() {
        return None;
    }
    Some((w, last_loss))
}

/// Classify a dataset with the (unmutated) predict graph and weights;
/// returns accuracy. Partial final batches are dropped (fixed-batch graph).
pub fn accuracy_on(
    predict: &Graph,
    spec: &TwoFcSpec,
    w: &TwoFcWeights,
    data: &crate::data::Dataset,
) -> f64 {
    let mut correct = 0usize;
    let mut total = 0usize;
    for (bi, (x, _)) in data.batches(spec.batch).iter().enumerate() {
        let inputs = vec![x.clone(), w.w1.clone(), w.b1.clone(), w.w2.clone(), w.b2.clone()];
        let Ok(out) = crate::interp::eval(predict, &inputs) else { return 0.0 };
        let preds = crate::tensor::ops::argmax_last(&out[0]);
        for (row, &p) in preds.data().iter().enumerate() {
            if p as usize == data.labels[bi * spec.batch + row] {
                correct += 1;
            }
            total += 1;
        }
    }
    correct as f64 / total.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::digits;

    fn small_spec() -> TwoFcSpec {
        TwoFcSpec { batch: 16, input: 196, hidden: 24, classes: 10, lr: 0.15 }
    }

    #[test]
    fn graphs_verify() {
        let spec = small_spec();
        crate::ir::verify::verify(&predict_graph(&spec)).unwrap();
        crate::ir::verify::verify(&train_step_graph(&spec)).unwrap();
    }

    #[test]
    fn labels_present_for_mutation_targets() {
        let g = train_step_graph(&small_spec());
        for lbl in ["grad_scale", "lr", "dense1", "dense2", "mean_loss"] {
            assert!(g.find_label(lbl).is_some(), "missing label {lbl}");
        }
    }

    #[test]
    fn training_reduces_loss_and_learns() {
        let spec = small_spec();
        let step = train_step_graph(&spec);
        let predict = predict_graph(&spec);
        let data = digits::generate(640, spec.side(), 11);
        let (train, test) = data.split(512);
        let batches = train.batches(spec.batch);
        let init = TwoFcWeights::init(&spec, 1);

        // loss after 0 epochs vs after 3
        let (_, loss1) = run_training(&step, &init, &batches[..4], 1).unwrap();
        let (w, loss2) = run_training(&step, &init, &batches, 3).unwrap();
        assert!(loss2 < loss1, "loss did not decrease: {loss1} -> {loss2}");

        let acc_init = accuracy_on(&predict, &spec, &init, &test);
        let acc_trained = accuracy_on(&predict, &spec, &w, &test);
        assert!(
            acc_trained > acc_init + 0.3,
            "training barely helped: {acc_init} -> {acc_trained}"
        );
        assert!(acc_trained > 0.6, "test accuracy only {acc_trained}");
    }

    #[test]
    fn grad_matches_finite_difference() {
        // Verify the hand-written backward pass: perturb one w2 entry and
        // compare d(loss)/dw against the graph's update step.
        let spec = TwoFcSpec { batch: 4, input: 6, hidden: 5, classes: 3, lr: 1.0 };
        let step = train_step_graph(&spec);
        let mut rng = Rng::new(3);
        let x = Tensor::rand_uniform(&[4, 6], 0.0, 1.0, &mut rng);
        let mut yv = vec![0.0f32; 12];
        for r in 0..4 {
            yv[r * 3 + r % 3] = 1.0;
        }
        let y = Tensor::new(crate::tensor::Shape::of(&[4, 3]), yv);
        let w = TwoFcWeights::init(&spec, 2);

        let run_loss = |w: &TwoFcWeights| -> f64 {
            let out = crate::interp::eval(
                &step,
                &[x.clone(), y.clone(), w.w1.clone(), w.b1.clone(), w.w2.clone(), w.b2.clone()],
            )
            .unwrap();
            out[4].item() as f64
        };
        // gradient from the update: dw2 = (w2 - new_w2) / lr ; lr = 1
        let out = crate::interp::eval(
            &step,
            &[x.clone(), y.clone(), w.w1.clone(), w.b1.clone(), w.w2.clone(), w.b2.clone()],
        )
        .unwrap();
        let analytic = w.w2.at(&[2, 1]) - out[2].at(&[2, 1]);
        // finite difference
        let eps = 1e-3f32;
        let mut wp = w.clone();
        wp.w2.set(&[2, 1], w.w2.at(&[2, 1]) + eps);
        let mut wm = w.clone();
        wm.w2.set(&[2, 1], w.w2.at(&[2, 1]) - eps);
        let numeric = (run_loss(&wp) - run_loss(&wm)) / (2.0 * eps as f64);
        assert!(
            (analytic as f64 - numeric).abs() < 2e-3,
            "grad mismatch: analytic {analytic} vs numeric {numeric}"
        );
    }

    #[test]
    fn fig5_mutation_applies_and_boosts_gradient() {
        let spec = small_spec();
        let mut g = train_step_graph(&spec);
        apply_fig5_gradient_mutation(&mut g).unwrap();
        crate::ir::verify::verify(&g).unwrap();
        // One step with the mutated graph must move weights ~B× further
        // than the baseline (grad no longer scaled by 1/B).
        let base = train_step_graph(&spec);
        let data = digits::generate(32, spec.side(), 3);
        let (x, y) = data.batch(&(0..16).collect::<Vec<_>>());
        let w = TwoFcWeights::init(&spec, 1);
        let ins = vec![x, y, w.w1.clone(), w.b1.clone(), w.w2.clone(), w.b2.clone()];
        let out_base = crate::interp::eval(&base, &ins).unwrap();
        let out_mut = crate::interp::eval(&g, &ins).unwrap();
        let delta = |out: &[Tensor]| -> f64 {
            out[2]
                .data()
                .iter()
                .zip(w.w2.data())
                .map(|(a, b)| (a - b).abs() as f64)
                .sum()
        };
        let (db, dm) = (delta(&out_base), delta(&out_mut));
        assert!(
            dm > db * 4.0,
            "mutated step should take much larger steps: base {db}, mutated {dm}"
        );
    }

    #[test]
    fn compiled_training_bit_identical_to_interp() {
        let spec = small_spec();
        let step = train_step_graph(&spec);
        let data = digits::generate(96, spec.side(), 5);
        let batches = data.batches(spec.batch);
        let init = TwoFcWeights::init(&spec, 1);
        let (wi, li) = run_training(&step, &init, &batches, 2).unwrap();
        let prog = crate::exec::Program::compile(&step).unwrap();
        let (wp, lp) = run_training_prog(&prog, &init, &batches, 2).unwrap();
        assert_eq!(li.to_bits(), lp.to_bits(), "loss diverged");
        for (a, b) in wi.as_vec().iter().zip(wp.as_vec().iter()) {
            assert_eq!(a.dims(), b.dims());
            assert!(
                a.data().iter().zip(b.data()).all(|(x, y)| x.to_bits() == y.to_bits()),
                "weights diverged between interp and compiled training"
            );
        }
    }

    #[test]
    fn census_matches_table1_shape() {
        // Table 1: 2fcNet = 2 fully-connected layers. Our census counts 2
        // dot ops in the forward pass.
        let g = predict_graph(&small_spec());
        assert_eq!(g.census()["dot"], 2);
    }
}
