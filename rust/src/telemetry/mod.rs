//! §Telemetry L1: zero-dependency observability for the search stack.
//!
//! Strictly *observational* facilities — none of them draws from
//! the search RNG or alters control flow, so trace-on and trace-off
//! (and profile-on and profile-off) runs are bit-identical (pinned by
//! `tests/telemetry_trace.rs` and `tests/measured_time.rs`):
//!
//! * [`spans`] — monotonic phase timers (propose / evaluate / select /
//!   migrate / checkpoint) aggregated per island with count / total /
//!   max and a log-bucketed streaming histogram. Recording is
//!   allocation-free: a fixed bucket array and a handful of integer
//!   adds per span.
//! * [`trace`] — the `--trace <path>.jsonl` event stream: one compact
//!   [`crate::util::json::Json`] record per generation / migration /
//!   checkpoint / cache sample, written by a dedicated writer thread
//!   behind a bounded channel (mirroring the durable checkpoint
//!   writer) so emitting an event never blocks an island barrier.
//! * [`profile`] — per-kernel execution profiles (`--profile`): the
//!   same count / total / max / log₂-bucket aggregation one layer down,
//!   keyed by compiled-program step kind, accumulated run-locally in
//!   the `exec` step loop and merged population-wide onto the
//!   `ProgramCache`.
//! * [`analyze`] — the aggregation behind `gevo-ml report
//!   <trace.jsonl>`: phase-time breakdowns, cache and operator-weight
//!   trajectories, hot-kernel tables and elite lineage tables in
//!   markdown or CSV.
//!
//! [`harness`] is the *measurement* sibling: the warmup + interleaved
//! A/B + MAD-filtered median-of-k wall-clock harness that `--metric
//! wall|blend` evaluation times candidates with, over an injectable
//! [`harness::Clock`].
//!
//! [`metrics`] holds the counter/timer registry (the operational
//! metrics a deployed search service exports), and
//! [`timing_noise`] characterizes the clock's noise floor (median/IQR
//! of back-to-back empty spans) so the future measured-wall-clock
//! metric has a documented resolution baseline (`perf_evo` reports it
//! into `BENCH_evo.json`).

pub mod analyze;
pub mod harness;
pub mod metrics;
pub mod profile;
pub mod spans;
pub mod trace;

pub use harness::{Clock, FixedStepClock, MonotonicClock, TimingHarness};
pub use metrics::Metrics;
pub use profile::{profile_summary, ProfileRow, ProfileSink, StepProfile};
pub use spans::{phase_summary, GenSpans, Phase, PhaseAgg, PhaseRow, SpanRecorder};
pub use trace::{event, TraceError, TraceWriter};

use std::time::Instant;

/// The clock's empirical noise floor: summary statistics over
/// back-to-back empty-span measurements (`Instant::now()` followed
/// immediately by `elapsed()`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimingNoise {
    /// Number of empty spans measured.
    pub samples: usize,
    /// Median empty-span duration in nanoseconds.
    pub median_ns: f64,
    /// Interquartile range (p75 − p25) in nanoseconds.
    pub iqr_ns: f64,
}

/// Measure the timing noise floor: the median and IQR of many
/// back-to-back empty spans. Any phase measurement below a few multiples
/// of `median_ns` is dominated by clock overhead, not work — the
/// wall-clock fitness metric (ROADMAP) must budget against this.
pub fn timing_noise() -> TimingNoise {
    const N: usize = 2048;
    let mut d = [0u64; N];
    for slot in d.iter_mut() {
        let t = Instant::now();
        *slot = t.elapsed().as_nanos() as u64;
    }
    d.sort_unstable();
    let q = |p: f64| d[((p * (N - 1) as f64).round() as usize).min(N - 1)] as f64;
    TimingNoise { samples: N, median_ns: q(0.5), iqr_ns: q(0.75) - q(0.25) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_noise_is_well_formed() {
        let n = timing_noise();
        assert_eq!(n.samples, 2048);
        assert!(n.median_ns >= 0.0 && n.median_ns.is_finite());
        assert!(n.iqr_ns >= 0.0 && n.iqr_ns.is_finite());
        // an empty span should resolve well under a millisecond on any
        // host this runs on; the bound is deliberately loose
        assert!(n.median_ns < 1e6, "empty span median {} ns", n.median_ns);
    }
}
