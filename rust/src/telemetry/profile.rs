//! §Telemetry L2b: per-kernel execution profiles — fixed-cost
//! aggregation of per-step timings inside compiled
//! [`crate::exec::Program`] runs, keyed by step kind. The shape mirrors
//! [`super::spans::PhaseAgg`] (count / total / max + log₂ buckets) one
//! layer down: spans say *where a generation's wall time went*, a step
//! profile says *which kernels inside evaluation cost what*.
//!
//! Recording is allocation-free — a fixed array of [`StepProfile`]s and
//! a handful of integer adds per step — and strictly observational: the
//! profiled execution paths compute exactly what the unprofiled ones
//! do, no RNG is drawn, and the `--profile` flag is excluded from the
//! checkpoint config echo, so profiled and unprofiled runs are
//! bit-identical in fronts, history, lineage and checkpoint bytes
//! (pinned by `tests/telemetry_trace.rs` and `tests/measured_time.rs`).

use super::spans::{bucket_of, HIST_BUCKETS};

/// Number of distinct step kinds a compiled program can execute — one
/// slot per `exec` `StepKind` variant. `exec::kind_index` maps a step
/// onto this array and has a unit test pinning the correspondence.
pub const KERNEL_KINDS: usize = 19;

/// Stable reporting names, in `exec` `StepKind` declaration order (the
/// order `exec::kind_index` indexes by).
pub const KERNEL_NAMES: [&str; KERNEL_KINDS] = [
    "param",
    "const",
    "map_bin",
    "map_un",
    "select",
    "dot2x2",
    "dot",
    "reshape",
    "broadcast",
    "transpose",
    "pad",
    "slice",
    "concat",
    "reduce",
    "conv2d",
    "depthwise_conv2d",
    "global_avg_pool",
    "fused_map",
    "dot_bias",
];

/// Streaming aggregate for one kernel kind: count / total / max plus a
/// log-bucketed duration histogram — [`super::spans::PhaseAgg`]'s shape,
/// made `Copy` so a [`ProfileSink`] is one flat array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepProfile {
    pub count: u64,
    pub total_ns: u64,
    pub max_ns: u64,
    pub buckets: [u64; HIST_BUCKETS],
}

impl StepProfile {
    pub const ZERO: StepProfile =
        StepProfile { count: 0, total_ns: 0, max_ns: 0, buckets: [0; HIST_BUCKETS] };

    /// Fold one step execution of `ns` nanoseconds into the aggregate.
    pub fn record(&mut self, ns: u64) {
        self.count += 1;
        self.total_ns = self.total_ns.saturating_add(ns);
        if ns > self.max_ns {
            self.max_ns = ns;
        }
        self.buckets[bucket_of(ns)] += 1;
    }

    /// Element-wise merge (for folding thread-local sinks together).
    pub fn merge(&mut self, other: &StepProfile) {
        self.count += other.count;
        self.total_ns = self.total_ns.saturating_add(other.total_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
    }
}

impl Default for StepProfile {
    fn default() -> Self {
        StepProfile::ZERO
    }
}

/// One profiling accumulator: a [`StepProfile`] per kernel kind.
/// Execution paths record into a run-local sink (no locking in the step
/// loop); the workload merges the sink into its
/// [`crate::exec::cache::ProgramCache`] once per evaluated run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProfileSink {
    kinds: [StepProfile; KERNEL_KINDS],
}

impl Default for ProfileSink {
    fn default() -> Self {
        ProfileSink { kinds: [StepProfile::ZERO; KERNEL_KINDS] }
    }
}

impl ProfileSink {
    pub fn new() -> ProfileSink {
        ProfileSink::default()
    }

    /// Fold one step of kind `kind` (an `exec::kind_index` value) taking
    /// `ns` nanoseconds.
    pub fn record(&mut self, kind: usize, ns: u64) {
        self.kinds[kind].record(ns);
    }

    /// Element-wise merge of another sink.
    pub fn merge(&mut self, other: &ProfileSink) {
        for (a, b) in self.kinds.iter_mut().zip(other.kinds.iter()) {
            a.merge(b);
        }
    }

    /// The aggregate for one kernel kind.
    pub fn get(&self, kind: usize) -> &StepProfile {
        &self.kinds[kind]
    }

    /// Total steps recorded across every kind.
    pub fn total_count(&self) -> u64 {
        self.kinds.iter().map(|k| k.count).sum()
    }

    /// Total nanoseconds recorded across every kind.
    pub fn total_ns(&self) -> u64 {
        let mut total = 0u64;
        for k in &self.kinds {
            total = total.saturating_add(k.total_ns);
        }
        total
    }

    pub fn is_empty(&self) -> bool {
        self.total_count() == 0
    }

    /// Flatten into reporting rows — one per kernel kind that recorded
    /// at least one step, in [`KERNEL_NAMES`] order (stable across runs
    /// so trace deltas and report tables diff cleanly).
    pub fn rows(&self) -> Vec<ProfileRow> {
        self.kinds
            .iter()
            .enumerate()
            .filter(|(_, k)| k.count > 0)
            .map(|(i, k)| ProfileRow {
                kernel: KERNEL_NAMES[i],
                count: k.count,
                total_ns: k.total_ns,
                max_ns: k.max_ns,
            })
            .collect()
    }
}

/// A flattened summary row for one kernel kind — what flows into
/// `SearchResult::profile`, the JSON report's `profile` section, the
/// `"profile"` trace event and the `gevo-ml report` hot-kernel table.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProfileRow {
    pub kernel: &'static str,
    pub count: u64,
    pub total_ns: u64,
    pub max_ns: u64,
}

/// The `profile:` one-liner printed by `gevo-ml search --profile`
/// (mirroring the `phases:` line): the top-3 kernel kinds by share of
/// total profiled time. CI greps the `profile: ` prefix.
pub fn profile_summary(rows: &[ProfileRow]) -> String {
    let total: u64 = rows.iter().map(|r| r.total_ns).sum();
    let steps: u64 = rows.iter().map(|r| r.count).sum();
    if steps == 0 {
        return "profile: no kernel steps recorded".to_string();
    }
    let mut busy: Vec<&ProfileRow> = rows.iter().filter(|r| r.count > 0).collect();
    busy.sort_by(|a, b| b.total_ns.cmp(&a.total_ns).then(a.kernel.cmp(b.kernel)));
    let parts: Vec<String> = busy
        .iter()
        .take(3)
        .map(|r| {
            format!(
                "{} {:.1}% ({:.3}s)",
                r.kernel,
                100.0 * r.total_ns as f64 / (total.max(1)) as f64,
                r.total_ns as f64 / 1e9
            )
        })
        .collect();
    format!(
        "profile: {} of {:.3}s across {} kernel steps",
        parts.join(", "),
        total as f64 / 1e9,
        steps
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_profile_mirrors_phase_agg_semantics() {
        let mut p = StepProfile::ZERO;
        p.record(10);
        p.record(1000);
        p.record(3);
        assert_eq!(p.count, 3);
        assert_eq!(p.total_ns, 1013);
        assert_eq!(p.max_ns, 1000);
        assert_eq!(p.buckets.iter().sum::<u64>(), 3);
        let mut q = StepProfile::ZERO;
        q.record(7);
        p.merge(&q);
        assert_eq!(p.count, 4);
        assert_eq!(p.total_ns, 1020);
    }

    #[test]
    fn sink_rows_skip_idle_kinds_and_keep_declaration_order() {
        let mut s = ProfileSink::new();
        s.record(6, 500); // "dot"
        s.record(2, 100); // "map_bin"
        s.record(6, 300);
        let rows = s.rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].kernel, "map_bin");
        assert_eq!(rows[1].kernel, "dot");
        assert_eq!(rows[1].count, 2);
        assert_eq!(rows[1].total_ns, 800);
        assert_eq!(rows[1].max_ns, 500);
        assert_eq!(s.total_count(), 3);
        assert_eq!(s.total_ns(), 900);
        assert!(!s.is_empty());
        assert!(ProfileSink::new().is_empty());
    }

    #[test]
    fn sink_merge_is_elementwise() {
        let mut a = ProfileSink::new();
        a.record(6, 10);
        let mut b = ProfileSink::new();
        b.record(6, 20);
        b.record(13, 5); // "reduce"
        a.merge(&b);
        assert_eq!(a.get(6).count, 2);
        assert_eq!(a.get(6).total_ns, 30);
        assert_eq!(a.get(13).count, 1);
        assert_eq!(a.rows().len(), 2);
    }

    #[test]
    fn kernel_names_cover_every_slot_uniquely() {
        assert_eq!(KERNEL_NAMES.len(), KERNEL_KINDS);
        let mut seen = std::collections::HashSet::new();
        for n in KERNEL_NAMES {
            assert!(seen.insert(n), "duplicate kernel name {n}");
            assert!(!n.is_empty());
        }
    }

    #[test]
    fn profile_summary_lists_top_shares_with_grep_stable_prefix() {
        let mut s = ProfileSink::new();
        s.record(6, 8_000); // dot
        s.record(2, 1_000); // map_bin
        s.record(13, 500); // reduce
        s.record(7, 400); // reshape
        s.record(4, 100); // select
        let line = profile_summary(&s.rows());
        assert!(line.starts_with("profile: "), "{line}");
        assert!(line.contains("dot 80.0%"), "{line}");
        assert!(line.contains("map_bin") && line.contains("reduce"), "{line}");
        assert!(!line.contains("select"), "only the top three appear: {line}");
        assert!(line.contains("5 kernel steps"), "{line}");
    }

    #[test]
    fn profile_summary_handles_empty_rows() {
        let line = profile_summary(&ProfileSink::new().rows());
        assert!(line.starts_with("profile: "), "{line}");
        assert!(line.contains("no kernel steps"), "{line}");
    }
}
