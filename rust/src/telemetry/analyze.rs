//! §Telemetry L3: trace-stream analysis — the aggregation behind
//! `gevo-ml report <trace.jsonl>`. Re-derives phase-time breakdowns,
//! cache-behavior and operator-weight trajectories, and the elite
//! lineage table from the JSONL event stream, rendered as markdown
//! (default) or CSV (`--csv`). Tolerant of partial traces: a killed
//! run's prefix (no `run_end`) still renders everything it recorded.

use crate::util::json::Json;

/// Render a parsed trace (one [`Json`] value per line, in file order)
/// as a markdown report, or as CSV sections with `csv = true`.
pub fn render(lines: &[Json], csv: bool) -> Result<String, String> {
    let t = Trace::gather(lines)?;
    Ok(if csv { t.to_csv() } else { t.to_markdown() })
}

fn num(j: &Json, key: &str) -> f64 {
    j.opt(key).and_then(|v| v.as_f64().ok()).unwrap_or(0.0)
}

fn int(j: &Json, key: &str) -> usize {
    num(j, key) as usize
}

struct GenRow {
    gen: usize,
    island: usize,
    evaluated: usize,
    valid: usize,
    front_size: usize,
    best_time: f64,
    best_error: f64,
    propose_ns: f64,
    evaluate_ns: f64,
    select_ns: f64,
    weights: Vec<f64>,
}

struct CacheRow {
    thru_gen: usize,
    pc_hits: f64,
    pc_misses: f64,
    memo_hits: f64,
    memo_misses: f64,
    filtered: f64,
    contended: f64,
    compile_ns: f64,
    batched: f64,
    scalar: f64,
}

struct KernelRow {
    kernel: String,
    count: f64,
    total_ns: f64,
    max_ns: f64,
}

struct FrontRow {
    time: f64,
    error: f64,
    island: usize,
    edits: usize,
    op: String,
    parent: String,
    edit: String,
}

struct Trace {
    operators: Vec<String>,
    islands: usize,
    resumes: usize,
    completed: usize,
    gens: Vec<GenRow>,
    caches: Vec<CacheRow>,
    migrations: Vec<(usize, f64)>,
    checkpoints: Vec<(usize, f64)>,
    front: Vec<FrontRow>,
    profile: Vec<KernelRow>,
    ended: bool,
}

impl Trace {
    fn gather(lines: &[Json]) -> Result<Trace, String> {
        if lines.is_empty() {
            return Err("empty trace (no events)".to_string());
        }
        let mut t = Trace {
            operators: Vec::new(),
            islands: 0,
            resumes: 0,
            completed: 0,
            gens: Vec::new(),
            caches: Vec::new(),
            migrations: Vec::new(),
            checkpoints: Vec::new(),
            front: Vec::new(),
            profile: Vec::new(),
            ended: false,
        };
        for (i, ev) in lines.iter().enumerate() {
            let kind = ev
                .get("kind")
                .and_then(|k| k.as_str())
                .map_err(|e| format!("event {}: {e}", i + 1))?
                .to_string();
            match kind.as_str() {
                "run_start" | "resume" => {
                    if kind == "resume" {
                        t.resumes += 1;
                    }
                    t.islands = t.islands.max(int(ev, "islands"));
                    if t.operators.is_empty() {
                        if let Some(ops) = ev.opt("operators").and_then(|o| o.as_arr().ok()) {
                            t.operators = ops
                                .iter()
                                .filter_map(|o| o.as_str().ok())
                                .map(str::to_string)
                                .collect();
                        }
                    }
                }
                "gen" => {
                    let ph = ev.opt("phase_ns");
                    let pick = |k: &str| ph.map(|p| num(p, k)).unwrap_or(0.0);
                    let weights = ev
                        .opt("weights")
                        .and_then(|w| w.as_arr().ok())
                        .map(|w| w.iter().filter_map(|x| x.as_f64().ok()).collect())
                        .unwrap_or_default();
                    t.gens.push(GenRow {
                        gen: int(ev, "gen"),
                        island: int(ev, "island"),
                        evaluated: int(ev, "evaluated"),
                        valid: int(ev, "valid"),
                        front_size: int(ev, "front_size"),
                        best_time: num(ev, "best_time"),
                        best_error: num(ev, "best_error"),
                        propose_ns: pick("propose"),
                        evaluate_ns: pick("evaluate"),
                        select_ns: pick("select"),
                        weights,
                    });
                }
                "cache" => t.caches.push(CacheRow {
                    thru_gen: int(ev, "thru_gen"),
                    pc_hits: num(ev, "pc_hits"),
                    pc_misses: num(ev, "pc_misses"),
                    memo_hits: num(ev, "memo_hits"),
                    memo_misses: num(ev, "memo_misses"),
                    filtered: num(ev, "filtered_neutral"),
                    contended: num(ev, "lock_contended"),
                    compile_ns: num(ev, "compile_ns"),
                    batched: num(ev, "batched_evals"),
                    scalar: num(ev, "scalar_evals"),
                }),
                "migration" => t.migrations.push((int(ev, "gen"), num(ev, "ns"))),
                "checkpoint" => t.checkpoints.push((int(ev, "gen"), num(ev, "ns"))),
                "front" => {
                    // the last front event wins (a resumed run re-emits it)
                    t.front.clear();
                    if let Some(pts) = ev.opt("points").and_then(|p| p.as_arr().ok()) {
                        for p in pts {
                            let lin = p.opt("lineage").filter(|l| !matches!(l, Json::Null));
                            let lstr = |k: &str| {
                                lin.and_then(|l| l.opt(k))
                                    .and_then(|v| v.as_str().ok())
                                    .unwrap_or("-")
                                    .to_string()
                            };
                            t.front.push(FrontRow {
                                time: num(p, "time"),
                                error: num(p, "error"),
                                island: int(p, "island"),
                                edits: int(p, "edits"),
                                op: lstr("op"),
                                parent: lstr("parent"),
                                edit: lstr("edit"),
                            });
                        }
                    }
                }
                "profile" => {
                    // rows are run-cumulative — the last event wins (a
                    // resumed or multi-segment run re-emits the totals)
                    t.profile.clear();
                    if let Some(ks) = ev.opt("kernels").and_then(|k| k.as_arr().ok()) {
                        for k in ks {
                            t.profile.push(KernelRow {
                                kernel: k
                                    .opt("kernel")
                                    .and_then(|v| v.as_str().ok())
                                    .unwrap_or("-")
                                    .to_string(),
                                count: num(k, "count"),
                                total_ns: num(k, "total_ns"),
                                max_ns: num(k, "max_ns"),
                            });
                        }
                    }
                }
                "run_end" => {
                    t.ended = true;
                    t.completed = t.completed.max(int(ev, "completed"));
                }
                other => return Err(format!("event {}: unknown kind '{other}'", i + 1)),
            }
        }
        Ok(t)
    }

    /// Phase rows rebuilt from the stream: (name, events, total_ns, max_ns).
    fn phase_rows(&self) -> Vec<(&'static str, u64, f64, f64)> {
        let fold = |f: fn(&GenRow) -> f64| -> (f64, f64) {
            self.gens.iter().map(f).fold((0.0, 0.0f64), |(s, m), x| (s + x, m.max(x)))
        };
        let (pt, pm) = fold(|g| g.propose_ns);
        let (et, em) = fold(|g| g.evaluate_ns);
        let (st, sm) = fold(|g| g.select_ns);
        let agg = |v: &[(usize, f64)]| -> (f64, f64) {
            v.iter().map(|&(_, ns)| ns).fold((0.0, 0.0f64), |(s, m), x| (s + x, m.max(x)))
        };
        let (mt, mm) = agg(&self.migrations);
        let (ct, cm) = agg(&self.checkpoints);
        let n = self.gens.len() as u64;
        vec![
            ("propose", n, pt, pm),
            ("evaluate", n, et, em),
            ("select", n, st, sm),
            ("migrate", self.migrations.len() as u64, mt, mm),
            ("checkpoint", self.checkpoints.len() as u64, ct, cm),
        ]
    }

    fn to_markdown(&self) -> String {
        let mut s = String::new();
        s.push_str("# gevo-ml trace report\n\n");
        s.push_str(&format!(
            "- events: {} gen, {} cache, {} migration, {} checkpoint, {} resume\n",
            self.gens.len(),
            self.caches.len(),
            self.migrations.len(),
            self.checkpoints.len(),
            self.resumes
        ));
        s.push_str(&format!(
            "- run: {} islands, {}\n\n",
            self.islands.max(1),
            if self.ended {
                format!("complete through generation {}", self.completed)
            } else {
                "no run_end event (killed or still running)".to_string()
            }
        ));

        // --- phases ---------------------------------------------------
        s.push_str("## phases\n\n");
        let rows = self.phase_rows();
        let total: f64 = rows.iter().map(|r| r.2).sum();
        s.push_str("| phase | events | total (ms) | mean (µs) | max (µs) | share |\n");
        s.push_str("|---|---|---|---|---|---|\n");
        for (name, n, tot, max) in &rows {
            let mean = if *n > 0 { tot / *n as f64 } else { 0.0 };
            let share = if total > 0.0 { 100.0 * tot / total } else { 0.0 };
            s.push_str(&format!(
                "| {name} | {n} | {:.3} | {:.1} | {:.1} | {share:.1}% |\n",
                tot / 1e6,
                mean / 1e3,
                max / 1e3
            ));
        }
        let prows: Vec<crate::telemetry::PhaseRow> = rows
            .iter()
            .map(|&(name, n, tot, max)| crate::telemetry::PhaseRow {
                phase: name,
                count: n,
                total_ns: tot as u64,
                max_ns: max as u64,
            })
            .collect();
        s.push_str(&format!("\n{}\n\n", crate::telemetry::phase_summary(&prows)));

        // --- cache ----------------------------------------------------
        s.push_str("## cache\n\n");
        if self.caches.is_empty() {
            s.push_str("no cache events recorded.\n\n");
        } else {
            s.push_str(
                "| thru gen | pc hits Δ | lowerings Δ | hit rate | memo hits Δ | \
                 memo misses Δ | filtered Δ | contended Δ | compile (ms) Δ | \
                 batched Δ | scalar Δ |\n",
            );
            s.push_str("|---|---|---|---|---|---|---|---|---|---|---|\n");
            for c in &self.caches {
                let probes = c.pc_hits + c.pc_misses;
                let rate = if probes > 0.0 { 100.0 * c.pc_hits / probes } else { 0.0 };
                s.push_str(&format!(
                    "| {} | {} | {} | {rate:.1}% | {} | {} | {} | {} | {:.3} | {} | {} |\n",
                    c.thru_gen,
                    c.pc_hits,
                    c.pc_misses,
                    c.memo_hits,
                    c.memo_misses,
                    c.filtered,
                    c.contended,
                    c.compile_ns / 1e6,
                    c.batched,
                    c.scalar
                ));
            }
            s.push('\n');
        }

        // --- hot kernels ----------------------------------------------
        s.push_str("## hot kernels\n\n");
        if self.profile.is_empty() {
            s.push_str("no profile events recorded (run with --profile --trace).\n\n");
        } else {
            let total: f64 = self.profile.iter().map(|k| k.total_ns).sum();
            let mut rows: Vec<&KernelRow> = self.profile.iter().collect();
            rows.sort_by(|a, b| {
                b.total_ns
                    .partial_cmp(&a.total_ns)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then_with(|| a.kernel.cmp(&b.kernel))
            });
            s.push_str("| kernel | steps | total (ms) | mean (µs) | max (µs) | share |\n");
            s.push_str("|---|---|---|---|---|---|\n");
            for k in rows {
                let mean = if k.count > 0.0 { k.total_ns / k.count } else { 0.0 };
                let share = if total > 0.0 { 100.0 * k.total_ns / total } else { 0.0 };
                s.push_str(&format!(
                    "| {} | {:.0} | {:.3} | {:.1} | {:.1} | {share:.1}% |\n",
                    k.kernel,
                    k.count,
                    k.total_ns / 1e6,
                    mean / 1e3,
                    k.max_ns / 1e3
                ));
            }
            s.push('\n');
        }

        // --- operator weights ----------------------------------------
        s.push_str("## operator weights\n\n");
        let with_weights: Vec<&GenRow> = self.gens.iter().filter(|g| !g.weights.is_empty()).collect();
        if with_weights.is_empty() {
            s.push_str("no operator-weight snapshots recorded.\n\n");
        } else {
            let nops =
                with_weights.iter().map(|g| g.weights.len()).max().unwrap_or(0);
            let names: Vec<String> = (0..nops)
                .map(|i| {
                    self.operators.get(i).cloned().unwrap_or_else(|| format!("op{i}"))
                })
                .collect();
            const MAX_ROWS: usize = 48;
            let mut shown = 0usize;
            let mut elided = 0usize;
            s.push_str(&format!("| island | gen | {} |\n", names.join(" | ")));
            s.push_str(&format!("|---|---|{}\n", "---|".repeat(nops)));
            for island in 0..self.islands.max(1) {
                // emit only rows where the weight vector moved
                let mut last: Option<&Vec<f64>> = None;
                for g in with_weights.iter().filter(|g| g.island == island) {
                    if last.map_or(false, |w| w == &g.weights) {
                        continue;
                    }
                    last = Some(&g.weights);
                    if shown >= MAX_ROWS {
                        elided += 1;
                        continue;
                    }
                    shown += 1;
                    let ws: Vec<String> =
                        (0..nops).map(|i| match g.weights.get(i) {
                            Some(w) => format!("{w:.3}"),
                            None => "-".to_string(),
                        }).collect();
                    s.push_str(&format!(
                        "| {island} | {} | {} |\n",
                        g.gen,
                        ws.join(" | ")
                    ));
                }
            }
            if elided > 0 {
                s.push_str(&format!("\n({elided} further weight changes elided)\n"));
            }
            s.push('\n');
        }

        // --- lineage --------------------------------------------------
        s.push_str("## lineage\n\n");
        if self.front.is_empty() {
            s.push_str("no front event recorded (run killed before completion?).\n");
        } else {
            s.push_str(
                "| runtime | error | island | edits | operator | parent | newest edit |\n",
            );
            s.push_str("|---|---|---|---|---|---|---|\n");
            for p in &self.front {
                s.push_str(&format!(
                    "| {:.4} | {:.4} | {} | {} | {} | {} | {} |\n",
                    p.time, p.error, p.island, p.edits, p.op, p.parent, p.edit
                ));
            }
        }
        s
    }

    fn to_csv(&self) -> String {
        let mut s = String::new();
        s.push_str("phase,events,total_ns,max_ns\n");
        for (name, n, tot, max) in self.phase_rows() {
            s.push_str(&format!("{name},{n},{tot:.0},{max:.0}\n"));
        }
        s.push('\n');
        s.push_str(
            "gen,island,evaluated,valid,front_size,best_time,best_error,\
             propose_ns,evaluate_ns,select_ns\n",
        );
        for g in &self.gens {
            s.push_str(&format!(
                "{},{},{},{},{},{},{},{:.0},{:.0},{:.0}\n",
                g.gen,
                g.island,
                g.evaluated,
                g.valid,
                g.front_size,
                g.best_time,
                g.best_error,
                g.propose_ns,
                g.evaluate_ns,
                g.select_ns
            ));
        }
        s.push('\n');
        s.push_str("front_time,front_error,island,edits,operator,parent,newest_edit\n");
        for p in &self.front {
            s.push_str(&format!(
                "{},{},{},{},{},{},\"{}\"\n",
                p.time, p.error, p.island, p.edits, p.op, p.parent, p.edit
            ));
        }
        s.push('\n');
        s.push_str("kernel,count,total_ns,max_ns\n");
        for k in &self.profile {
            s.push_str(&format!(
                "{},{:.0},{:.0},{:.0}\n",
                k.kernel, k.count, k.total_ns, k.max_ns
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::event;

    fn synthetic() -> Vec<Json> {
        vec![
            event(
                "run_start",
                vec![
                    ("islands", Json::num(2.0)),
                    ("generations", Json::num(2.0)),
                    (
                        "operators",
                        Json::arr(vec![Json::str("copy"), Json::str("delete")]),
                    ),
                ],
            ),
            event(
                "gen",
                vec![
                    ("gen", Json::num(0.0)),
                    ("island", Json::num(0.0)),
                    ("evaluated", Json::num(6.0)),
                    ("valid", Json::num(5.0)),
                    ("front_size", Json::num(2.0)),
                    ("best_time", Json::num(1.0)),
                    ("best_error", Json::num(0.0)),
                    (
                        "phase_ns",
                        Json::obj(vec![
                            ("propose", Json::num(1000.0)),
                            ("evaluate", Json::num(8000.0)),
                            ("select", Json::num(500.0)),
                        ]),
                    ),
                    ("weights", Json::arr(vec![Json::num(0.5), Json::num(0.5)])),
                ],
            ),
            event(
                "gen",
                vec![
                    ("gen", Json::num(1.0)),
                    ("island", Json::num(0.0)),
                    ("evaluated", Json::num(6.0)),
                    ("valid", Json::num(6.0)),
                    ("front_size", Json::num(3.0)),
                    ("best_time", Json::num(0.9)),
                    ("best_error", Json::num(0.0)),
                    (
                        "phase_ns",
                        Json::obj(vec![
                            ("propose", Json::num(1200.0)),
                            ("evaluate", Json::num(7000.0)),
                            ("select", Json::num(600.0)),
                        ]),
                    ),
                    ("weights", Json::arr(vec![Json::num(0.7), Json::num(0.3)])),
                ],
            ),
            event(
                "cache",
                vec![
                    ("thru_gen", Json::num(2.0)),
                    ("pc_hits", Json::num(10.0)),
                    ("pc_misses", Json::num(2.0)),
                    ("compile_ns", Json::num(5e6)),
                ],
            ),
            event("migration", vec![("gen", Json::num(2.0)), ("ns", Json::num(4000.0))]),
            event("checkpoint", vec![("gen", Json::num(2.0)), ("ns", Json::num(9000.0))]),
            event(
                "front",
                vec![(
                    "points",
                    Json::arr(vec![Json::obj(vec![
                        ("time", Json::num(0.9)),
                        ("error", Json::num(0.0)),
                        ("island", Json::num(0.0)),
                        ("edits", Json::num(1.0)),
                        (
                            "lineage",
                            Json::obj(vec![
                                ("op", Json::str("delete")),
                                ("parent", Json::str("00000000deadbeef")),
                                ("edit", Json::str("del(%5)")),
                            ]),
                        ),
                    ])]),
                )],
            ),
            event(
                "profile",
                vec![
                    ("thru_gen", Json::num(2.0)),
                    (
                        "kernels",
                        Json::arr(vec![
                            Json::obj(vec![
                                ("kernel", Json::str("dot")),
                                ("count", Json::num(128.0)),
                                ("total_ns", Json::num(9e6)),
                                ("max_ns", Json::num(80000.0)),
                            ]),
                            Json::obj(vec![
                                ("kernel", Json::str("map_bin")),
                                ("count", Json::num(256.0)),
                                ("total_ns", Json::num(1e6)),
                                ("max_ns", Json::num(10000.0)),
                            ]),
                        ]),
                    ),
                ],
            ),
            event("run_end", vec![("completed", Json::num(2.0))]),
        ]
    }

    #[test]
    fn markdown_renders_every_section() {
        let md = render(&synthetic(), false).unwrap();
        assert!(md.contains("# gevo-ml trace report"), "{md}");
        assert!(md.contains("## phases"), "{md}");
        assert!(md.contains("## cache"), "{md}");
        assert!(md.contains("## hot kernels"), "{md}");
        assert!(md.contains("## operator weights"), "{md}");
        assert!(md.contains("## lineage"), "{md}");
        assert!(md.contains("phases: evaluate"), "top phase must lead: {md}");
        assert!(md.contains("| dot | 128 | 9.000 |"), "hot-kernel row: {md}");
        assert!(md.contains("90.0%"), "dominant kernel share: {md}");
        assert!(md.contains("| delete |"), "operator column: {md}");
        assert!(md.contains("00000000deadbeef"), "parent fingerprint: {md}");
    }

    #[test]
    fn later_profile_event_replaces_earlier() {
        // profile rows are run-cumulative snapshots, so only the latest
        // event should survive — mirroring the "front" semantics.
        let mut lines = synthetic();
        let end = lines.pop().unwrap(); // keep run_end last
        lines.push(event(
            "profile",
            vec![
                ("thru_gen", Json::num(4.0)),
                (
                    "kernels",
                    Json::arr(vec![Json::obj(vec![
                        ("kernel", Json::str("dot")),
                        ("count", Json::num(999.0)),
                        ("total_ns", Json::num(2e7)),
                        ("max_ns", Json::num(90000.0)),
                    ])]),
                ),
            ],
        ));
        lines.push(end);
        let csv = render(&lines, true).unwrap();
        assert!(csv.contains("dot,999,20000000,90000"), "{csv}");
        assert!(!csv.contains("dot,128,"), "{csv}");
        assert!(!csv.contains("map_bin"), "latest snapshot wins wholesale: {csv}");
    }

    #[test]
    fn weight_trajectory_skips_unchanged_rows() {
        let mut lines = synthetic();
        // duplicate the last gen event with identical weights — the
        // trajectory table must not grow a row for it
        let dup = lines[2].clone();
        lines.insert(3, dup);
        let md = render(&lines, false).unwrap();
        let weight_rows =
            md.lines().filter(|l| l.starts_with("| 0 |")).count();
        assert_eq!(weight_rows, 2, "{md}");
    }

    #[test]
    fn csv_mode_emits_phase_and_gen_sections() {
        let csv = render(&synthetic(), true).unwrap();
        assert!(csv.starts_with("phase,events,total_ns,max_ns\n"), "{csv}");
        assert!(csv.contains("\ngen,island,"), "{csv}");
        assert!(csv.contains("\nfront_time,"), "{csv}");
        assert!(csv.contains("evaluate,2,15000,8000"), "{csv}");
        assert!(csv.contains("\nkernel,count,total_ns,max_ns\n"), "{csv}");
        assert!(csv.contains("dot,128,9000000,80000"), "{csv}");
        assert!(csv.contains("map_bin,256,1000000,10000"), "{csv}");
    }

    #[test]
    fn empty_and_malformed_traces_are_clean_errors() {
        assert!(render(&[], false).is_err());
        let bogus = vec![Json::obj(vec![("kind", Json::str("nonsense"))])];
        let e = render(&bogus, false).err().unwrap();
        assert!(e.contains("unknown kind"), "{e}");
        let missing = vec![Json::obj(vec![("gen", Json::num(1.0))])];
        assert!(render(&missing, false).is_err());
    }

    #[test]
    fn partial_trace_without_run_end_still_renders() {
        let mut lines = synthetic();
        lines.truncate(3); // run_start + two gens, killed before the end
        let md = render(&lines, false).unwrap();
        assert!(md.contains("no run_end event"), "{md}");
        assert!(md.contains("no front event"), "{md}");
    }
}
