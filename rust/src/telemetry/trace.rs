//! §Telemetry L2: the JSONL trace stream. A dedicated writer thread
//! behind a bounded channel (the same shape as the island runtime's
//! durable checkpoint writer) appends one compact JSON record per
//! event, so emitting never blocks a migration/checkpoint barrier for
//! file I/O. The file is opened at spawn time: a bogus `--trace` path
//! fails fast with a clean error instead of panicking mid-run.

use crate::util::json::Json;
use std::io::Write;
use std::path::Path;
use std::sync::mpsc;
use std::thread::JoinHandle;

/// How many events may queue before a submit blocks. Events are small
/// (one generation each); a deep queue keeps barriers from ever waiting
/// on disk under normal operation.
const TRACE_QUEUE: usize = 256;

/// A trace-stream failure: opening the file, writing a record, or a
/// dead writer thread. Stringly-typed like the island runtime's
/// checkpoint errors; the CLI maps it to a clean `error:` exit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceError(String);

impl TraceError {
    fn new(msg: impl Into<String>) -> Self {
        TraceError(msg.into())
    }
}

impl std::fmt::Display for TraceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "trace: {}", self.0)
    }
}

impl std::error::Error for TraceError {}

/// Build a trace event: a JSON object with the mandatory `"kind"`
/// discriminator plus the given fields. Compact-serialized, one per
/// line — CI greps for `"kind":"gen"` etc.
pub fn event(kind: &str, fields: Vec<(&str, Json)>) -> Json {
    let mut all = vec![("kind", Json::str(kind))];
    all.extend(fields);
    Json::obj(all)
}

/// Asynchronous JSONL appender. Events submitted here are serialized
/// and written by a background thread; `drain` joins it and surfaces
/// any deferred I/O error. Dropping the writer drains best-effort.
///
/// The file is opened in append mode so a resumed run extends the
/// trace of the run it continues (the `"resume"` event marks the
/// boundary).
pub struct TraceWriter {
    tx: Option<mpsc::SyncSender<Json>>,
    handle: Option<JoinHandle<Result<(), TraceError>>>,
}

impl TraceWriter {
    /// Open `path` for appending and start the writer thread. Fails
    /// immediately (no thread spawned) if the file cannot be opened.
    pub fn spawn(path: &Path) -> Result<TraceWriter, TraceError> {
        let shown = path.display().to_string();
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| TraceError::new(format!("{shown}: {e}")))?;
        let (tx, rx) = mpsc::sync_channel::<Json>(TRACE_QUEUE);
        let handle = std::thread::Builder::new()
            .name("gevo-trace-writer".to_string())
            .spawn(move || -> Result<(), TraceError> {
                let mut w = std::io::BufWriter::new(file);
                while let Ok(ev) = rx.recv() {
                    // line-buffered: a killed run leaves a well-formed
                    // prefix of complete records
                    writeln!(w, "{}", ev.to_string())
                        .and_then(|_| w.flush())
                        .map_err(|e| TraceError::new(format!("{shown}: {e}")))?;
                }
                let file = w
                    .into_inner()
                    .map_err(|e| TraceError::new(format!("{shown}: {e}")))?;
                file.sync_all()
                    .map_err(|e| TraceError::new(format!("{shown}: {e}")))?;
                Ok(())
            })
            .map_err(|e| TraceError::new(format!("writer thread: {e}")))?;
        Ok(TraceWriter { tx: Some(tx), handle: Some(handle) })
    }

    /// Queue one event. A send failure means the writer thread died on
    /// an earlier record; the deferred error is joined and returned.
    pub fn submit(&mut self, ev: Json) -> Result<(), TraceError> {
        let alive = match &self.tx {
            Some(tx) => tx.send(ev).is_ok(),
            None => false,
        };
        if alive {
            return Ok(());
        }
        match self.drain() {
            Err(e) => Err(e),
            Ok(()) => Err(TraceError::new("writer thread exited early")),
        }
    }

    /// Close the channel, join the writer, and surface any I/O error.
    /// Idempotent: a second drain is a no-op `Ok`.
    pub fn drain(&mut self) -> Result<(), TraceError> {
        self.tx = None; // close the channel so the thread's recv loop ends
        match self.handle.take() {
            None => Ok(()),
            Some(h) => match h.join() {
                Ok(r) => r,
                Err(_) => Err(TraceError::new("writer thread panicked")),
            },
        }
    }
}

impl Drop for TraceWriter {
    fn drop(&mut self) {
        let _ = self.drain();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("gevo_trace_unit_{tag}_{}.jsonl", std::process::id()))
    }

    #[test]
    fn writes_one_parseable_record_per_line() {
        let p = tmp("lines");
        let _ = std::fs::remove_file(&p);
        let mut w = TraceWriter::spawn(&p).unwrap();
        w.submit(event("gen", vec![("gen", Json::num(0.0)), ("island", Json::num(1.0))]))
            .unwrap();
        w.submit(event("run_end", vec![("completed", Json::num(3.0))])).unwrap();
        w.drain().unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("kind").unwrap().as_str().unwrap(), "gen");
        assert!(lines[0].contains("\"kind\":\"gen\""), "compact kind field: {}", lines[0]);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn append_mode_extends_an_existing_trace() {
        let p = tmp("append");
        let _ = std::fs::remove_file(&p);
        for kind in ["run_start", "resume"] {
            let mut w = TraceWriter::spawn(&p).unwrap();
            w.submit(event(kind, vec![])).unwrap();
            w.drain().unwrap();
        }
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.contains("\"kind\":\"run_start\""));
        assert!(text.contains("\"kind\":\"resume\""));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn bogus_path_fails_at_spawn_not_at_submit() {
        let p = std::path::Path::new("/nonexistent_gevo_dir/deeper/trace.jsonl");
        let err = TraceWriter::spawn(p);
        assert!(err.is_err(), "spawning onto an unwritable path must fail fast");
        let msg = format!("{}", err.err().unwrap());
        assert!(msg.contains("trace:"), "{msg}");
    }

    #[test]
    fn drain_is_idempotent() {
        let p = tmp("drain");
        let _ = std::fs::remove_file(&p);
        let mut w = TraceWriter::spawn(&p).unwrap();
        w.submit(event("run_start", vec![])).unwrap();
        w.drain().unwrap();
        w.drain().unwrap();
        let _ = std::fs::remove_file(&p);
    }
}
