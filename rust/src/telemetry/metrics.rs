//! §Telemetry L2: the counter/timer registry — the operational metrics
//! a deployed search service would export. This module is the canonical
//! home (the historical `coordinator::metrics` shim is gone); the lock
//! sites recover from poisoning with the same discipline as
//! `exec::ProgramCache` — a panicking holder can only leave a counter
//! map mid-update, never structurally broken, so continuing with the
//! recovered guard is strictly better than cascading the panic. The
//! old free-floating global `EVALS` counter was never wired to the
//! eval pool and has been removed; per-run evaluation counts flow
//! through `SearchResult::total_evaluations` instead.

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Instant;

fn unpoisoned<'a, T>(
    r: Result<MutexGuard<'a, T>, PoisonError<MutexGuard<'a, T>>>,
) -> MutexGuard<'a, T> {
    r.unwrap_or_else(|p| p.into_inner())
}

/// A named set of monotonically-increasing counters and duration sums.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    durations_us: Mutex<BTreeMap<String, u64>>,
    start: Option<Instant>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics { start: Some(Instant::now()), ..Default::default() }
    }

    pub fn inc(&self, name: &str, by: u64) {
        *unpoisoned(self.counters.lock()).entry(name.to_string()).or_insert(0) += by;
    }

    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        let us = t0.elapsed().as_micros() as u64;
        *unpoisoned(self.durations_us.lock()).entry(name.to_string()).or_insert(0) += us;
        out
    }

    pub fn counter(&self, name: &str) -> u64 {
        *unpoisoned(self.counters.lock()).get(name).unwrap_or(&0)
    }

    pub fn duration_secs(&self, name: &str) -> f64 {
        *unpoisoned(self.durations_us.lock()).get(name).unwrap_or(&0) as f64 / 1e6
    }

    /// One-line-per-metric report.
    pub fn report(&self) -> String {
        let mut s = String::new();
        if let Some(start) = self.start {
            s.push_str(&format!("uptime_secs: {:.3}\n", start.elapsed().as_secs_f64()));
        }
        for (k, v) in unpoisoned(self.counters.lock()).iter() {
            s.push_str(&format!("{k}: {v}\n"));
        }
        for (k, v) in unpoisoned(self.durations_us.lock()).iter() {
            s.push_str(&format!("{k}_secs: {:.3}\n", *v as f64 / 1e6));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_timers() {
        let m = Metrics::new();
        m.inc("evals", 3);
        m.inc("evals", 2);
        assert_eq!(m.counter("evals"), 5);
        let out = m.time("work", || 7);
        assert_eq!(out, 7);
        assert!(m.duration_secs("work") >= 0.0);
        let rep = m.report();
        assert!(rep.contains("evals: 5"));
        assert!(rep.contains("work_secs:"));
    }

    #[test]
    fn poisoned_registry_keeps_counting() {
        // a panicking closure inside `time` poisons nothing structural:
        // both maps stay usable afterwards
        let m = std::sync::Arc::new(Metrics::new());
        let m2 = std::sync::Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            // poison the counters mutex by panicking while it is held
            let _guard = m2.counters.lock().unwrap();
            panic!("injected panic while holding the counters lock");
        })
        .join();
        m.inc("after", 1);
        assert_eq!(m.counter("after"), 1);
        assert!(m.report().contains("after: 1"));
    }
}
