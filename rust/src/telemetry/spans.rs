//! §Telemetry L2: phase spans — fixed-cost aggregation of monotonic
//! timings around the search's phases. Recording a span is a handful of
//! integer adds into a fixed-size struct: no allocation, no locking, no
//! RNG — safe to leave on unconditionally in the hot path.

/// The instrumented phases of one search run. `Propose`, `Evaluate`
/// and `Select` are recorded per island per generation inside
/// `Engine::step`; `Migrate` and `Checkpoint` happen on the driver
/// thread at segment barriers. Compile time is tracked separately by
/// `exec::ProgramCache::compile_ns` (it nests *inside* `Evaluate`, so
/// it is not a disjoint phase).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Propose,
    Evaluate,
    Select,
    Migrate,
    Checkpoint,
}

impl Phase {
    /// Every phase, in reporting order.
    pub const ALL: [Phase; 5] =
        [Phase::Propose, Phase::Evaluate, Phase::Select, Phase::Migrate, Phase::Checkpoint];

    /// Stable lowercase name used in traces, reports and summaries.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Propose => "propose",
            Phase::Evaluate => "evaluate",
            Phase::Select => "select",
            Phase::Migrate => "migrate",
            Phase::Checkpoint => "checkpoint",
        }
    }

    fn index(self) -> usize {
        self as usize
    }
}

/// Number of log₂ histogram buckets: bucket `b ≥ 1` holds spans in
/// `[2^(b−1), 2^b)` ns, bucket 0 holds zero-length spans, and the last
/// bucket absorbs everything ≥ 2^38 ns (~4.6 min — far beyond any
/// single phase span).
pub const HIST_BUCKETS: usize = 40;

/// Which histogram bucket a span of `ns` nanoseconds lands in.
pub fn bucket_of(ns: u64) -> usize {
    (64 - ns.leading_zeros() as usize).min(HIST_BUCKETS - 1)
}

/// Streaming aggregate for one phase: count / total / max plus a
/// log-bucketed duration histogram. Fixed size, `Copy`-free but
/// allocation-free; merging two aggregates is element-wise.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PhaseAgg {
    pub count: u64,
    pub total_ns: u64,
    pub max_ns: u64,
    pub buckets: [u64; HIST_BUCKETS],
}

impl Default for PhaseAgg {
    fn default() -> Self {
        PhaseAgg { count: 0, total_ns: 0, max_ns: 0, buckets: [0; HIST_BUCKETS] }
    }
}

impl PhaseAgg {
    /// Fold one span of `ns` nanoseconds into the aggregate.
    pub fn record(&mut self, ns: u64) {
        self.count += 1;
        self.total_ns = self.total_ns.saturating_add(ns);
        if ns > self.max_ns {
            self.max_ns = ns;
        }
        self.buckets[bucket_of(ns)] += 1;
    }

    /// Element-wise merge of another aggregate (for cross-island sums).
    pub fn merge(&mut self, other: &PhaseAgg) {
        self.count += other.count;
        self.total_ns = self.total_ns.saturating_add(other.total_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
    }
}

/// A flattened summary row for one phase — what flows into
/// `SearchResult::phases`, the JSON report, and the `phases:` line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PhaseRow {
    pub phase: &'static str,
    pub count: u64,
    pub total_ns: u64,
    pub max_ns: u64,
}

/// One engine generation's phase timings plus the operator-weight
/// snapshot taken at the end of the step — staged by each engine and
/// drained by the island driver at the next barrier to build `"gen"`
/// trace events. Purely observational.
#[derive(Debug, Clone, PartialEq)]
pub struct GenSpans {
    pub gen: usize,
    pub propose_ns: u64,
    pub evaluate_ns: u64,
    pub select_ns: u64,
    pub weights: Vec<f64>,
}

/// Per-owner span store: one [`PhaseAgg`] per [`Phase`]. Engines own
/// one each (propose/evaluate/select); the island driver owns one for
/// migrate/checkpoint; they merge into the run total at the end.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanRecorder {
    aggs: [PhaseAgg; 5],
}

impl Default for SpanRecorder {
    fn default() -> Self {
        SpanRecorder {
            aggs: [
                PhaseAgg::default(),
                PhaseAgg::default(),
                PhaseAgg::default(),
                PhaseAgg::default(),
                PhaseAgg::default(),
            ],
        }
    }
}

impl SpanRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one span into the given phase's aggregate.
    pub fn record(&mut self, phase: Phase, ns: u64) {
        self.aggs[phase.index()].record(ns);
    }

    /// The aggregate for one phase.
    pub fn get(&self, phase: Phase) -> &PhaseAgg {
        &self.aggs[phase.index()]
    }

    /// Merge another recorder (element-wise across phases).
    pub fn merge(&mut self, other: &SpanRecorder) {
        for (a, b) in self.aggs.iter_mut().zip(other.aggs.iter()) {
            a.merge(b);
        }
    }

    /// Total instrumented nanoseconds across all phases.
    pub fn total_ns(&self) -> u64 {
        self.aggs.iter().map(|a| a.total_ns).sum()
    }

    /// Flatten into reporting rows, one per phase in [`Phase::ALL`]
    /// order (rows with zero events are included so the schema is
    /// stable).
    pub fn rows(&self) -> Vec<PhaseRow> {
        Phase::ALL
            .iter()
            .map(|&p| {
                let a = self.get(p);
                PhaseRow {
                    phase: p.name(),
                    count: a.count,
                    total_ns: a.total_ns,
                    max_ns: a.max_ns,
                }
            })
            .collect()
    }
}

/// The `phases:` one-liner printed by `gevo-ml search` (mirroring the
/// `batch:` line): the top-3 phases by share of total instrumented
/// time. CI greps the `phases: ` prefix.
pub fn phase_summary(rows: &[PhaseRow]) -> String {
    let total: u64 = rows.iter().map(|r| r.total_ns).sum();
    if total == 0 {
        return "phases: no span data recorded".to_string();
    }
    let mut busy: Vec<&PhaseRow> = rows.iter().filter(|r| r.count > 0).collect();
    busy.sort_by(|a, b| b.total_ns.cmp(&a.total_ns).then(a.phase.cmp(b.phase)));
    let parts: Vec<String> = busy
        .iter()
        .take(3)
        .map(|r| {
            format!(
                "{} {:.1}% ({:.3}s)",
                r.phase,
                100.0 * r.total_ns as f64 / total as f64,
                r.total_ns as f64 / 1e9
            )
        })
        .collect();
    format!("phases: {} of {:.3}s instrumented", parts.join(", "), total as f64 / 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_log2_and_clamped() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn record_tracks_count_total_max_and_histogram() {
        let mut a = PhaseAgg::default();
        a.record(10);
        a.record(1000);
        a.record(3);
        assert_eq!(a.count, 3);
        assert_eq!(a.total_ns, 1013);
        assert_eq!(a.max_ns, 1000);
        assert_eq!(a.buckets.iter().sum::<u64>(), 3);
        assert_eq!(a.buckets[bucket_of(10)] + a.buckets[bucket_of(3)], 2);
    }

    #[test]
    fn merge_is_elementwise() {
        let mut a = PhaseAgg::default();
        a.record(5);
        let mut b = PhaseAgg::default();
        b.record(7);
        b.record(9000);
        a.merge(&b);
        assert_eq!(a.count, 3);
        assert_eq!(a.total_ns, 5 + 7 + 9000);
        assert_eq!(a.max_ns, 9000);
        assert_eq!(a.buckets.iter().sum::<u64>(), 3);
    }

    #[test]
    fn recorder_rows_cover_every_phase_in_order() {
        let mut r = SpanRecorder::new();
        r.record(Phase::Evaluate, 100);
        r.record(Phase::Propose, 10);
        let rows = r.rows();
        assert_eq!(rows.len(), 5);
        assert_eq!(rows[0].phase, "propose");
        assert_eq!(rows[1].phase, "evaluate");
        assert_eq!(rows[1].total_ns, 100);
        assert_eq!(rows[4].phase, "checkpoint");
        assert_eq!(r.total_ns(), 110);
    }

    #[test]
    fn phase_summary_lists_top_shares() {
        let mut r = SpanRecorder::new();
        r.record(Phase::Evaluate, 8_000);
        r.record(Phase::Propose, 1_000);
        r.record(Phase::Select, 500);
        r.record(Phase::Migrate, 400);
        r.record(Phase::Checkpoint, 100);
        let s = phase_summary(&r.rows());
        assert!(s.starts_with("phases: "), "{s}");
        assert!(s.contains("evaluate 80.0%"), "{s}");
        // only the top three phases appear
        assert!(s.contains("propose") && s.contains("select"), "{s}");
        assert!(!s.contains("checkpoint"), "{s}");
    }

    #[test]
    fn phase_summary_handles_empty_recorder() {
        let s = phase_summary(&SpanRecorder::new().rows());
        assert!(s.starts_with("phases: "), "{s}");
    }
}
