//! §Telemetry L2c: the noise-robust wall-clock harness behind
//! `--metric wall|blend`.
//!
//! A single unwarmed `Instant` shot — the historical measured-time path
//! — is noisy enough that [`super::timing_noise`] exists to document
//! how noisy. This harness applies the standard discipline (GEVO and
//! KernelFoundry both search on measured runtime, PAPERS.md): warmup
//! iterations to populate caches and branch predictors, median-of-k
//! sampling with MAD outlier rejection, and interleaved A/B ordering so
//! a baseline/candidate comparison cancels slow clock drift (thermal
//! ramps, background load) instead of attributing it to whichever side
//! ran second.
//!
//! The clock is injected through the [`Clock`] trait: production code
//! uses [`MonotonicClock`] (a monotonic `Instant` origin), tests use
//! [`FixedStepClock`] to make every measured duration — and therefore
//! every `--metric wall` objective — a deterministic function of clock
//! call counts (pinned by `tests/measured_time.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// A nanosecond clock the harness samples around closures. `Send +
/// Sync` because workloads (which hold a harness) are shared across the
/// evaluation worker pool.
pub trait Clock: Send + Sync {
    /// Nanoseconds since an arbitrary fixed origin; must never go
    /// backwards between two calls on the same thread.
    fn now_ns(&self) -> u64;
}

/// The production clock: nanoseconds since construction, monotonic.
#[derive(Debug)]
pub struct MonotonicClock {
    origin: Instant,
}

impl MonotonicClock {
    pub fn new() -> MonotonicClock {
        MonotonicClock { origin: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        MonotonicClock::new()
    }
}

impl Clock for MonotonicClock {
    fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }
}

/// A deterministic test clock: every `now_ns` call advances time by a
/// fixed step, so any span measured around a closure is exactly
/// `step_ns` regardless of real elapsed time. With single-threaded
/// search settings this makes measured-time objectives reproducible
/// bit-for-bit.
#[derive(Debug)]
pub struct FixedStepClock {
    calls: AtomicU64,
    step_ns: u64,
}

impl FixedStepClock {
    pub fn new(step_ns: u64) -> FixedStepClock {
        FixedStepClock { calls: AtomicU64::new(0), step_ns }
    }

    /// How many times the clock has been read (test observability).
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }
}

impl Clock for FixedStepClock {
    fn now_ns(&self) -> u64 {
        let n = self.calls.fetch_add(1, Ordering::Relaxed);
        (n + 1).saturating_mul(self.step_ns)
    }
}

/// Noise-robust measurement of closures. `measure` times one closure;
/// `measure_ab` times a baseline/candidate pair in strict interleaved
/// order. Both return the MAD-filtered median over `samples` timed
/// repetitions after `warmup` untimed ones, or `None` if the closure
/// ever reports failure.
#[derive(Clone)]
pub struct TimingHarness {
    clock: Arc<dyn Clock>,
    /// Untimed runs before sampling starts (cache/branch warmup).
    pub warmup: usize,
    /// Timed repetitions per measurement; the reported value is their
    /// robust median. Treated as at least 1.
    pub samples: usize,
    /// MAD outlier threshold: samples farther than `mad_k ×
    /// median-absolute-deviation` from the median are discarded before
    /// the final median. 3.5 is the conventional cutoff.
    pub mad_k: f64,
}

impl std::fmt::Debug for TimingHarness {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TimingHarness")
            .field("warmup", &self.warmup)
            .field("samples", &self.samples)
            .field("mad_k", &self.mad_k)
            .finish()
    }
}

impl TimingHarness {
    /// The production configuration: monotonic clock, 1 warmup run,
    /// median of 5 samples, 3.5×MAD rejection.
    pub fn monotonic() -> TimingHarness {
        TimingHarness::with_clock(Arc::new(MonotonicClock::new()))
    }

    /// Same defaults over an injected clock (deterministic in tests).
    pub fn with_clock(clock: Arc<dyn Clock>) -> TimingHarness {
        TimingHarness { clock, warmup: 1, samples: 5, mad_k: 3.5 }
    }

    fn time_once<F: FnMut() -> bool>(&self, f: &mut F) -> Option<f64> {
        let t0 = self.clock.now_ns();
        if !f() {
            return None;
        }
        let t1 = self.clock.now_ns();
        Some(t1.saturating_sub(t0) as f64 / 1e9)
    }

    /// Robust wall-clock seconds of `f`: warmup, then the MAD-filtered
    /// median of `samples` timed runs. `None` as soon as `f` reports
    /// failure (a failing variant has no meaningful runtime).
    pub fn measure<F: FnMut() -> bool>(&self, mut f: F) -> Option<f64> {
        for _ in 0..self.warmup {
            if !f() {
                return None;
            }
        }
        let n = self.samples.max(1);
        let mut xs = Vec::with_capacity(n);
        for _ in 0..n {
            xs.push(self.time_once(&mut f)?);
        }
        Some(robust_median(&mut xs, self.mad_k))
    }

    /// Paired measurement in strict interleaved order — warmup runs
    /// a,b,a,b,…, then each timed round times `a` then `b` — so slow
    /// drift over the measurement window hits both sides equally and
    /// cancels out of their ratio. Returns `(median_a, median_b)`.
    pub fn measure_ab<A, B>(&self, mut a: A, mut b: B) -> Option<(f64, f64)>
    where
        A: FnMut() -> bool,
        B: FnMut() -> bool,
    {
        for _ in 0..self.warmup {
            if !a() || !b() {
                return None;
            }
        }
        let n = self.samples.max(1);
        let mut xa = Vec::with_capacity(n);
        let mut xb = Vec::with_capacity(n);
        for _ in 0..n {
            xa.push(self.time_once(&mut a)?);
            xb.push(self.time_once(&mut b)?);
        }
        Some((robust_median(&mut xa, self.mad_k), robust_median(&mut xb, self.mad_k)))
    }
}

/// Median after MAD outlier rejection: discard samples farther than
/// `mad_k × MAD` from the raw median, then take the median of what
/// survives. The raw median always survives its own filter, so the kept
/// set is never empty; a zero MAD (at least half the samples identical)
/// keeps the raw median. Sorts `xs` in place.
pub fn robust_median(xs: &mut [f64], mad_k: f64) -> f64 {
    assert!(!xs.is_empty(), "robust_median of no samples");
    xs.sort_unstable_by(f64::total_cmp);
    let med = median_of_sorted(xs);
    let mut devs: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    devs.sort_unstable_by(f64::total_cmp);
    let mad = median_of_sorted(&devs);
    if !(mad > 0.0) {
        return med;
    }
    let kept: Vec<f64> = xs.iter().copied().filter(|x| (x - med).abs() <= mad_k * mad).collect();
    median_of_sorted(&kept)
}

fn median_of_sorted(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n % 2 == 1 {
        xs[n / 2]
    } else {
        (xs[n / 2 - 1] + xs[n / 2]) / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;

    #[test]
    fn monotonic_clock_never_goes_backwards() {
        let c = MonotonicClock::new();
        let mut last = 0u64;
        for _ in 0..100 {
            let t = c.now_ns();
            assert!(t >= last);
            last = t;
        }
    }

    #[test]
    fn fixed_clock_makes_measurements_exact_and_reproducible() {
        for _ in 0..2 {
            let clock = Arc::new(FixedStepClock::new(1_000));
            let h = TimingHarness::with_clock(clock.clone());
            let w = h.measure(|| true).unwrap();
            // each timed sample spans exactly one clock step
            assert_eq!(w.to_bits(), (1_000.0f64 / 1e9).to_bits());
            // warmup draws no clock reads; each of the 5 samples draws 2
            assert_eq!(clock.calls(), 10);
        }
    }

    #[test]
    fn failing_closure_yields_none_in_warmup_and_in_samples() {
        let h = TimingHarness::with_clock(Arc::new(FixedStepClock::new(10)));
        assert_eq!(h.measure(|| false), None);
        let mut n = 0;
        // succeed through warmup (1 run), fail on the third timed sample
        assert_eq!(
            h.measure(|| {
                n += 1;
                n != 4
            }),
            None
        );
        assert_eq!(h.measure_ab(|| true, || false), None);
        assert_eq!(h.measure_ab(|| false, || true), None);
    }

    #[test]
    fn robust_median_rejects_outliers_plain_median_keeps() {
        // plain median of [1,2,3,1000] is 2.5; the 1000 outlier is
        // MAD-rejected, leaving median(1,2,3) = 2
        let mut xs = [1.0, 2.0, 3.0, 1000.0];
        assert_eq!(robust_median(&mut xs, 3.5), 2.0);
    }

    #[test]
    fn robust_median_with_zero_mad_keeps_the_median() {
        let mut xs = [10.0, 10.0, 10.0, 10.0, 9999.0];
        assert_eq!(robust_median(&mut xs, 3.5), 10.0);
        let mut one = [42.0];
        assert_eq!(robust_median(&mut one, 3.5), 42.0);
    }

    #[test]
    fn measure_ab_interleaves_strictly() {
        let log = RefCell::new(String::new());
        let mut h = TimingHarness::with_clock(Arc::new(FixedStepClock::new(7)));
        h.warmup = 1;
        h.samples = 2;
        let (wa, wb) = h
            .measure_ab(
                || {
                    log.borrow_mut().push('a');
                    true
                },
                || {
                    log.borrow_mut().push('b');
                    true
                },
            )
            .unwrap();
        assert_eq!(*log.borrow(), "ababab", "warmup pair then two timed rounds");
        assert_eq!(wa.to_bits(), (7.0f64 / 1e9).to_bits());
        assert_eq!(wb.to_bits(), (7.0f64 / 1e9).to_bits());
    }

    #[test]
    fn zero_samples_is_treated_as_one() {
        let mut h = TimingHarness::with_clock(Arc::new(FixedStepClock::new(5)));
        h.samples = 0;
        h.warmup = 0;
        assert!(h.measure(|| true).is_some());
    }
}
