//! §Perf: the graph-optimizer pipeline on the fitness hot path —
//! instruction-count reduction, ProgramCache hit-rate uplift, the
//! optimize-memo effect, compile-path cost, and `--opt-level 3` kernel
//! fusion (step-count / peak-buffer reduction and eval throughput vs
//! O0/O2) on both seed workload graphs, over a population-shaped stream
//! of mutants. Writes a machine-readable summary to `BENCH_opt.json`
//! next to the human-readable table.

use gevo_ml::evo::mutate::valid_random_edit;
use gevo_ml::evo::nsga2::Objectives;
use gevo_ml::evo::search::{self, SearchConfig};
use gevo_ml::exec::cache::ProgramCache;
use gevo_ml::exec::{Program, Scratch};
use gevo_ml::ir::{Graph, OpKind};
use gevo_ml::models::{mobilenet, twofc};
use gevo_ml::opt::{optimize, OptLevel};
use gevo_ml::tensor::Tensor;
use gevo_ml::util::bench::{black_box, Bench};
use gevo_ml::util::json::Json;
use gevo_ml::util::rng::Rng;

/// Seeded mutants: chains of 1..=4 valid edits on the train-step graph.
fn mutants(base: &Graph, n: usize, seed: u64) -> Vec<Graph> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut g = base.clone();
            for _ in 0..rng.range(1, 5) {
                if let Some((_, ng)) = valid_random_edit(&g, &mut rng, 25) {
                    g = ng;
                }
            }
            g
        })
        .collect()
}

/// A generation-shaped lookup stream: each mutant, a twin differing only
/// by a dead instruction (what neutral edits produce), and the baseline
/// again (elite re-selection). O0 sees three distinct keys per triple;
/// the optimizing cache collapses the first two.
fn stream(base: &Graph, pop: &[Graph]) -> Vec<Graph> {
    let mut out = Vec::with_capacity(pop.len() * 3);
    for g in pop {
        out.push(g.clone());
        let mut twin = g.clone();
        let anchor = twin.insts()[0].id;
        let _ = twin.push(OpKind::Exponential, &[anchor]);
        out.push(twin);
        out.push(base.clone());
    }
    out
}

fn main() {
    let mut b = Bench::new("perf_opt");
    let spec = twofc::TwoFcSpec { batch: 8, input: 16, hidden: 16, classes: 10, lr: 0.1 };
    let base = twofc::train_step_graph(&spec);
    let pop = mutants(&base, 48, 0x0917);
    let looks = stream(&base, &pop);

    // --- per-graph compile path: optimize (+ compile) cost ------------------
    b.case("optimize train-step (opt-level 1)", || {
        black_box(optimize(&base, OptLevel::O1));
    });
    b.case("optimize train-step (opt-level 2)", || {
        black_box(optimize(&base, OptLevel::O2));
    });
    b.case("compile train-step raw (O0 path)", || {
        black_box(Program::compile(&base).unwrap());
    });
    b.case("optimize O2 + compile train-step", || {
        let (og, _) = optimize(&base, OptLevel::O2);
        black_box(Program::compile(&og).unwrap());
    });
    b.case("optimize O3 + compile_fused train-step", || {
        let (og, _) = optimize(&base, OptLevel::O3);
        black_box(Program::compile_fused(&og).unwrap());
    });

    // --- the population cache, cold, at O0 / O2 / O3 ------------------------
    let mut level_rows: Vec<Json> = Vec::new();
    for level in [OptLevel::O0, OptLevel::O2, OptLevel::O3] {
        let cache = ProgramCache::with_opt(level);
        let t0 = std::time::Instant::now();
        for g in &looks {
            black_box(cache.get_or_compile(g).unwrap());
        }
        let cold_secs = t0.elapsed().as_secs_f64();
        let (hits, misses) = cache.stats();
        let o = cache.opt_stats();
        let hit_rate = hits as f64 / (hits + misses).max(1) as f64;
        let reduction = if o.insts_in > 0 {
            1.0 - o.insts_out as f64 / o.insts_in as f64
        } else {
            0.0
        };
        b.note(&format!(
            "opt-level {level}: {} lookups -> {hits} hits / {misses} lowerings \
             (hit rate {:.1}%), insts {} -> {} ({:.1}% removed), memo {} hits / {} \
             pipeline runs, cold pass {:.3}s",
            looks.len(),
            hit_rate * 100.0,
            o.insts_in,
            o.insts_out,
            reduction * 100.0,
            o.memo_hits,
            o.memo_misses,
            cold_secs
        ));
        let mut row = vec![
            ("opt_level", Json::num(level.as_u8() as f64)),
            ("lookups", Json::num(looks.len() as f64)),
            ("hits", Json::num(hits as f64)),
            ("misses", Json::num(misses as f64)),
            ("hit_rate", Json::num(hit_rate)),
            ("insts_in", Json::num(o.insts_in as f64)),
            ("insts_out", Json::num(o.insts_out as f64)),
            ("instruction_reduction", Json::num(reduction)),
            ("memo_hits", Json::num(o.memo_hits as f64)),
            ("memo_misses", Json::num(o.memo_misses as f64)),
            ("cold_seconds", Json::num(cold_secs)),
        ];
        if let Some(f) = cache.fusion_stats() {
            let step_reduction = if f.steps_before > 0 {
                1.0 - f.steps_after as f64 / f.steps_before as f64
            } else {
                0.0
            };
            b.note(&format!(
                "opt-level {level} fusion: {} regions / {} programs, steps {} -> {} \
                 ({:.1}% fewer), peak buffers {} -> {}",
                f.regions,
                f.programs,
                f.steps_before,
                f.steps_after,
                step_reduction * 100.0,
                f.peak_before,
                f.peak_after
            ));
            row.push(("fusion_regions", Json::num(f.regions as f64)));
            row.push(("fusion_steps_before", Json::num(f.steps_before as f64)));
            row.push(("fusion_steps_after", Json::num(f.steps_after as f64)));
            row.push(("fusion_step_reduction", Json::num(step_reduction)));
            row.push(("fusion_peak_before", Json::num(f.peak_before as f64)));
            row.push(("fusion_peak_after", Json::num(f.peak_after as f64)));
        }
        level_rows.push(Json::obj(row));
    }

    // --- warm cache throughput (everything memo-hits) ------------------------
    for level in [OptLevel::O0, OptLevel::O2, OptLevel::O3] {
        let cache = ProgramCache::with_opt(level);
        for g in &looks {
            let _ = cache.get_or_compile(g).unwrap();
        }
        b.case(&format!("warm cache stream x{} (opt-level {level})", looks.len()), || {
            for g in &looks {
                black_box(cache.get_or_compile(g).unwrap());
            }
        });
    }

    // --- O3 fusion on both seed workload graphs ------------------------------
    // Step-count / peak-buffer reduction and single-eval throughput of the
    // fused lowering vs the unfused one, on the exact graphs the paper's
    // two experiments evolve.
    let mspec = mobilenet::MobileNetSpec { batch: 2, side: 8, classes: 4, width: 4, blocks: 2 };
    let mweights = mobilenet::random_weights(&mspec, 3);
    let workloads: Vec<(&str, Graph)> = vec![
        ("2fcnet train-step", base.clone()),
        ("mobilenet predict", mobilenet::predict_graph(&mspec, &mweights)),
    ];
    let mut fusion_rows: Vec<Json> = Vec::new();
    for (name, g) in &workloads {
        let (og, _) = optimize(g, OptLevel::O3);
        let unfused = Program::compile(&og).unwrap();
        let fused = Program::compile_fused(&og).unwrap();
        let f = fused.fusion_stats().expect("fused compile records stats");
        let mut rng = Rng::new(0x0F15E);
        let inputs: Vec<Tensor> = og
            .param_types()
            .iter()
            .map(|t| Tensor::rand_uniform(&t.dims, 0.0, 1.0, &mut rng))
            .collect();
        let time_runs = |p: &Program| -> f64 {
            let mut scratch = Scratch::new();
            let _ = black_box(p.run_with(&inputs, &mut scratch).unwrap()); // warm-up
            let t0 = std::time::Instant::now();
            const RUNS: usize = 50;
            for _ in 0..RUNS {
                black_box(p.run_with(&inputs, &mut scratch).unwrap());
            }
            t0.elapsed().as_secs_f64() / RUNS as f64
        };
        let (t_unfused, t_fused) = (time_runs(&unfused), time_runs(&fused));
        b.note(&format!(
            "{name}: O3 fusion {} regions, steps {} -> {}, peak {} -> {}, \
             eval {:.1}us -> {:.1}us ({:.2}x)",
            f.regions,
            f.steps_before,
            f.steps_after,
            f.peak_before,
            f.peak_after,
            t_unfused * 1e6,
            t_fused * 1e6,
            t_unfused / t_fused.max(1e-12)
        ));
        fusion_rows.push(Json::obj(vec![
            ("workload", Json::str(*name)),
            ("regions", Json::num(f.regions as f64)),
            ("steps_before", Json::num(f.steps_before as f64)),
            ("steps_after", Json::num(f.steps_after as f64)),
            ("peak_before", Json::num(f.peak_before as f64)),
            ("peak_after", Json::num(f.peak_after as f64)),
            ("eval_seconds_unfused", Json::num(t_unfused)),
            ("eval_seconds_fused", Json::num(t_fused)),
        ]));
    }

    // --- per-operator proposal economics (adaptive scheduler) ----------------
    // A short full-registry adaptive search over the train-step graph with
    // the deterministic flops/error toy objective: which operators propose,
    // which get accepted, and what fraction of their evaluations move the
    // objectives (the 2208.12350 non-neutral rate, per operator).
    let op_rows: Vec<Json> = {
        let base_flops = base.total_flops() as f64;
        let mut in_rng = Rng::new(0x0B5);
        let inputs: Vec<Tensor> = base
            .param_types()
            .iter()
            .map(|t| Tensor::rand_uniform(&t.dims, 0.0, 1.0, &mut in_rng))
            .collect();
        let baseline_out = gevo_ml::interp::eval(&base, &inputs).expect("baseline runs");
        let eval = move |vg: &Graph| -> Option<Objectives> {
            let out = gevo_ml::interp::eval(vg, &inputs).ok()?;
            let mut err = 0.0f64;
            for (o, b) in out.iter().zip(baseline_out.iter()) {
                if o.has_non_finite() {
                    return None;
                }
                err += o.max_abs_diff(b) as f64;
            }
            Some((vg.total_flops() as f64 / base_flops, err))
        };
        let cfg = SearchConfig {
            pop_size: 16,
            generations: 6,
            elites: 6,
            workers: 1,
            seed: 0x0917,
            adapt: true,
            operators: gevo_ml::evo::operators::registry()
                .iter()
                .map(|(n, _, _)| (*n).to_string())
                .collect(),
            verbose: false,
            ..Default::default()
        };
        let r = search::run(&base, &eval, &cfg);
        r.operators
            .iter()
            .map(|o| {
                let nn_frac = if o.evals > 0 {
                    o.non_neutral as f64 / o.evals as f64
                } else {
                    0.0
                };
                b.note(&format!(
                    "operator {:<10} weight {:<6} proposals {:>4} accepts {:>4} \
                     evals {:>4} non-neutral {:>4} ({:.0}%) inserts {:>3}",
                    o.name,
                    o.weight.map_or("-".into(), |w| format!("{w:.3}")),
                    o.proposals,
                    o.accepts,
                    o.evals,
                    o.non_neutral,
                    nn_frac * 100.0,
                    o.inserts
                ));
                Json::obj(vec![
                    ("operator", Json::str(o.name.clone())),
                    ("weight", o.weight.map_or(Json::Null, Json::num)),
                    ("proposals", Json::num(o.proposals as f64)),
                    ("accepts", Json::num(o.accepts as f64)),
                    ("evaluated", Json::num(o.evals as f64)),
                    ("non_neutral", Json::num(o.non_neutral as f64)),
                    ("non_neutral_fraction", Json::num(nn_frac)),
                    ("archive_inserts", Json::num(o.inserts as f64)),
                ])
            })
            .collect()
    };

    let summary = Json::obj(vec![
        ("suite", Json::str("perf_opt")),
        ("workload", Json::str("2fcnet train-step")),
        ("population", Json::num(pop.len() as f64)),
        ("levels", Json::Arr(level_rows)),
        ("fusion", Json::Arr(fusion_rows)),
        ("operators", Json::Arr(op_rows)),
    ]);
    std::fs::write("BENCH_opt.json", summary.to_pretty()).expect("write BENCH_opt.json");
    b.note("wrote BENCH_opt.json");
    b.finish();
}
