//! §Perf: the graph-optimizer pipeline on the fitness hot path —
//! instruction-count reduction, ProgramCache hit-rate uplift and
//! compile-path cost at `--opt-level 2` vs `0`, over a population-shaped
//! stream of mutants. Writes a machine-readable summary to
//! `BENCH_opt.json` next to the human-readable table.

use gevo_ml::evo::mutate::valid_random_edit;
use gevo_ml::exec::cache::ProgramCache;
use gevo_ml::ir::{Graph, OpKind};
use gevo_ml::models::twofc;
use gevo_ml::opt::{optimize, OptLevel};
use gevo_ml::util::bench::{black_box, Bench};
use gevo_ml::util::json::Json;
use gevo_ml::util::rng::Rng;

/// Seeded mutants: chains of 1..=4 valid edits on the train-step graph.
fn mutants(base: &Graph, n: usize, seed: u64) -> Vec<Graph> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut g = base.clone();
            for _ in 0..rng.range(1, 5) {
                if let Some((_, ng)) = valid_random_edit(&g, &mut rng, 25) {
                    g = ng;
                }
            }
            g
        })
        .collect()
}

/// A generation-shaped lookup stream: each mutant, a twin differing only
/// by a dead instruction (what neutral edits produce), and the baseline
/// again (elite re-selection). O0 sees three distinct keys per triple;
/// the optimizing cache collapses the first two.
fn stream(base: &Graph, pop: &[Graph]) -> Vec<Graph> {
    let mut out = Vec::with_capacity(pop.len() * 3);
    for g in pop {
        out.push(g.clone());
        let mut twin = g.clone();
        let anchor = twin.insts()[0].id;
        let _ = twin.push(OpKind::Exponential, &[anchor]);
        out.push(twin);
        out.push(base.clone());
    }
    out
}

fn main() {
    let mut b = Bench::new("perf_opt");
    let spec = twofc::TwoFcSpec { batch: 8, input: 16, hidden: 16, classes: 10, lr: 0.1 };
    let base = twofc::train_step_graph(&spec);
    let pop = mutants(&base, 48, 0x0917);
    let looks = stream(&base, &pop);

    // --- per-graph compile path: optimize (+ compile) cost ------------------
    b.case("optimize train-step (opt-level 1)", || {
        black_box(optimize(&base, OptLevel::O1));
    });
    b.case("optimize train-step (opt-level 2)", || {
        black_box(optimize(&base, OptLevel::O2));
    });
    b.case("compile train-step raw (O0 path)", || {
        black_box(gevo_ml::exec::Program::compile(&base).unwrap());
    });
    b.case("optimize O2 + compile train-step", || {
        let (og, _) = optimize(&base, OptLevel::O2);
        black_box(gevo_ml::exec::Program::compile(&og).unwrap());
    });

    // --- the population cache, cold, at both levels -------------------------
    let mut level_rows: Vec<Json> = Vec::new();
    for level in [OptLevel::O0, OptLevel::O2] {
        let cache = ProgramCache::with_opt(level);
        let t0 = std::time::Instant::now();
        for g in &looks {
            black_box(cache.get_or_compile(g).unwrap());
        }
        let cold_secs = t0.elapsed().as_secs_f64();
        let (hits, misses) = cache.stats();
        let (ins_in, ins_out) = cache.opt_stats();
        let hit_rate = hits as f64 / (hits + misses).max(1) as f64;
        let reduction = if ins_in > 0 {
            1.0 - ins_out as f64 / ins_in as f64
        } else {
            0.0
        };
        b.note(&format!(
            "opt-level {level}: {} lookups -> {hits} hits / {misses} lowerings \
             (hit rate {:.1}%), insts {ins_in} -> {ins_out} ({:.1}% removed), \
             cold pass {:.3}s",
            looks.len(),
            hit_rate * 100.0,
            reduction * 100.0,
            cold_secs
        ));
        level_rows.push(Json::obj(vec![
            ("opt_level", Json::num(level.as_u8() as f64)),
            ("lookups", Json::num(looks.len() as f64)),
            ("hits", Json::num(hits as f64)),
            ("misses", Json::num(misses as f64)),
            ("hit_rate", Json::num(hit_rate)),
            ("insts_in", Json::num(ins_in as f64)),
            ("insts_out", Json::num(ins_out as f64)),
            ("instruction_reduction", Json::num(reduction)),
            ("cold_seconds", Json::num(cold_secs)),
        ]));
    }

    // --- warm cache throughput (everything hits) -----------------------------
    for level in [OptLevel::O0, OptLevel::O2] {
        let cache = ProgramCache::with_opt(level);
        for g in &looks {
            let _ = cache.get_or_compile(g).unwrap();
        }
        b.case(&format!("warm cache stream x{} (opt-level {level})", looks.len()), || {
            for g in &looks {
                black_box(cache.get_or_compile(g).unwrap());
            }
        });
    }

    let summary = Json::obj(vec![
        ("suite", Json::str("perf_opt")),
        ("workload", Json::str("2fcnet train-step")),
        ("population", Json::num(pop.len() as f64)),
        ("levels", Json::Arr(level_rows)),
    ]);
    std::fs::write("BENCH_opt.json", summary.to_pretty()).expect("write BENCH_opt.json");
    b.note("wrote BENCH_opt.json");
    b.finish();
}
