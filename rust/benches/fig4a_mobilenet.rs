//! Fig. 4a regeneration (scaled): Pareto front for MobileNet prediction.
//! The full-budget run is `cargo run --release --example evolve_mobilenet`;
//! this bench runs a reduced budget so `cargo bench` completes quickly,
//! and prints the front rows the figure plots.

use gevo_ml::coordinator::{self, report, ExperimentConfig, WorkloadKind};
use gevo_ml::evo::search::SearchConfig;
use gevo_ml::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig4a_mobilenet_prediction");
    b.samples = 1;
    b.warmup = 0;

    let cfg = ExperimentConfig {
        kind: WorkloadKind::MobilenetPrediction,
        search: SearchConfig {
            pop_size: 16,
            generations: 8,
            elites: 8,
            seed: 42,
            verbose: false,
            ..Default::default()
        },
        fit_samples: 256,
        test_samples: 96,
        epochs: 1,
        ..Default::default()
    };
    let mut result = None;
    b.case("search pop=16 gens=8 (scaled Fig. 4a)", || {
        result = Some(coordinator::run_experiment(&cfg));
    });
    let r = result.unwrap();
    b.note(&format!(
        "baseline: runtime {:.4} error {:.4} (orange diamond)",
        r.baseline_fit.0, r.baseline_fit.1
    ));
    for (i, p) in r.front.iter().enumerate() {
        b.note(&format!(
            "front[{i}]: runtime {:.4} error {:.4} (edits {})",
            p.fit.0, p.fit.1, p.edits
        ));
    }
    let base_err = r.baseline_post_hoc.map(|o| o.1).unwrap_or(r.baseline_fit.1);
    let best_rt = r
        .front
        .iter()
        .filter(|p| p.post_hoc.map(|o| o.1 <= base_err + 0.02).unwrap_or(false))
        .map(|p| p.fit.0)
        .fold(f64::INFINITY, f64::min);
    b.note(&format!(
        "headline: paper 1.90x speedup @2% accuracy budget; ours {}",
        if best_rt.is_finite() && best_rt > 0.0 {
            format!("{:.2}x (ratio {best_rt:.4})", 1.0 / best_rt)
        } else {
            "none within budget at this reduced bench scale".into()
        }
    ));
    b.note(&format!("evaluations: {}", r.search.total_evaluations));
    let _ = report::front_csv(&r);
    b.finish();
}
