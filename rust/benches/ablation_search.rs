//! Ablations over GEVO-ML's design choices (DESIGN.md experiment index):
//!
//! * dead-code elimination in `materialize` — off ⇒ deletions cannot
//!   compound into runtime savings;
//! * elitism size (paper: 16) — 0 vs 4 vs 16;
//! * initial mutations per individual (paper: 3) — 1 vs 3 vs 6;
//! * crossover on/off — §4.2 claims messy crossover diversifies cheaply.
//!
//! Workload: 2fcNet training fitness at reduced scale; metric = best
//! same-runtime training error and best overall runtime on the final
//! front, at a fixed evaluation budget.

use gevo_ml::coordinator::{self, ExperimentConfig, WorkloadKind};
use gevo_ml::evo::search::SearchConfig;
use gevo_ml::util::bench::Bench;

fn run(cfg_mod: impl Fn(&mut SearchConfig)) -> (f64, f64, usize) {
    let mut search = SearchConfig {
        pop_size: 16,
        generations: 8,
        elites: 8,
        workers: 2,
        seed: 42,
        verbose: false,
        ..Default::default()
    };
    cfg_mod(&mut search);
    let cfg = ExperimentConfig {
        kind: WorkloadKind::TwoFcTraining,
        search,
        fit_samples: 256,
        test_samples: 96,
        epochs: 1,
        ..Default::default()
    };
    let r = coordinator::run_experiment(&cfg);
    let best_equal_rt = r
        .front
        .iter()
        .filter(|p| p.fit.0 <= r.baseline_fit.0 * 1.001)
        .map(|p| p.fit.1)
        .fold(r.baseline_fit.1, f64::min);
    let best_rt = r.front.iter().map(|p| p.fit.0).fold(f64::INFINITY, f64::min);
    (best_equal_rt, best_rt, r.search.total_evaluations)
}

fn main() {
    let mut b = Bench::new("ablation_search");
    b.samples = 1;
    b.warmup = 0;

    let cases: Vec<(&str, Box<dyn Fn(&mut SearchConfig)>)> = vec![
        ("paper defaults (elites=8, init=3, xover=.6)", Box::new(|_c: &mut SearchConfig| {})),
        ("no elitism", Box::new(|c: &mut SearchConfig| c.elites = 0)),
        ("heavy elitism (=pop)", Box::new(|c: &mut SearchConfig| c.elites = 16)),
        ("init mutations = 1", Box::new(|c: &mut SearchConfig| c.init_mutations = 1)),
        ("init mutations = 6", Box::new(|c: &mut SearchConfig| c.init_mutations = 6)),
        ("no crossover", Box::new(|c: &mut SearchConfig| c.crossover_prob = 0.0)),
        ("crossover always", Box::new(|c: &mut SearchConfig| c.crossover_prob = 1.0)),
    ];
    for (name, m) in cases {
        let mut out = (0.0, 0.0, 0);
        b.case(&format!("search [{name}]"), || {
            out = run(&m);
        });
        b.note(&format!(
            "  {name}: best-equal-runtime-err {:.4}, best-runtime {:.4}, evals {}",
            out.0, out.1, out.2
        ));
    }
    b.note("expected: elitism and crossover each improve the front at fixed budget");
    b.finish();
}
