//! §Perf L3: interpreter hot-path micro-benchmarks — GEMM roofline, conv
//! kernels, and the two workload inner loops whose wall-clock dominates
//! every fitness evaluation.

use gevo_ml::coordinator;
use gevo_ml::data::{digits, patterns};
use gevo_ml::exec;
use gevo_ml::models::{mobilenet, twofc};
use gevo_ml::tensor::{ops, Tensor};
use gevo_ml::util::bench::{black_box, Bench};
use gevo_ml::util::rng::Rng;

/// Compiled-vs-interpreter re-execution on a 2fcNet forward graph: build
/// both engines over the same graph/inputs and report the throughput
/// ratio (the ISSUE-1 acceptance bar is ≥ 2× on the fitness-loop shape).
fn compiled_vs_interp(b: &mut Bench, rng: &mut Rng, spec: &twofc::TwoFcSpec, tag: &str) {
    let fwd = twofc::predict_graph(spec);
    let w = twofc::TwoFcWeights::init(spec, 1);
    let x = Tensor::rand_uniform(&[spec.batch, spec.input], 0.0, 1.0, rng);
    let inputs = vec![x, w.w1, w.b1, w.w2, w.b2];
    let prog = exec::Program::compile(&fwd).expect("forward graph compiles");
    let mut scratch = exec::Scratch::new();
    const REPS: usize = 200;
    let work = fwd.total_flops() as f64 * REPS as f64;
    let ti = b.case_with_work(&format!("2fcnet fwd {tag} x{REPS} (interp)"), Some(work), || {
        for _ in 0..REPS {
            black_box(gevo_ml::interp::eval(&fwd, &inputs).unwrap());
        }
    });
    let te = b.case_with_work(&format!("2fcnet fwd {tag} x{REPS} (compiled)"), Some(work), || {
        for _ in 0..REPS {
            black_box(prog.run_with(&inputs, &mut scratch).unwrap());
        }
    });
    b.note(&format!(
        "compiled re-execution speedup vs interp::eval [{tag}]: {:.2}x",
        ti / te.max(1e-12)
    ));
}

fn main() {
    let mut b = Bench::new("perf_interp");
    let mut rng = Rng::new(1);

    // --- compiled engine vs interpreter (ISSUE-1 acceptance: >= 2x) -------
    // Fitness-loop shape: the inner loop re-executes small graphs
    // thousands of times, where per-instruction overhead (hashmap env,
    // per-op allocation, defensive clones) dominates the arithmetic.
    let small = twofc::TwoFcSpec { batch: 8, input: 16, hidden: 16, classes: 10, lr: 0.1 };
    compiled_vs_interp(&mut b, &mut rng, &small, "16-in");
    // Reference point at the default experiment scale (GEMM-dominated, so
    // the ratio shrinks toward 1 as arithmetic swamps overhead).
    let dflt = twofc::TwoFcSpec::default();
    compiled_vs_interp(&mut b, &mut rng, &dflt, "196-in");

    // --- GEMM roofline -----------------------------------------------------
    for (m, k, n) in [(32, 196, 32), (32, 32, 10), (128, 128, 128), (256, 256, 256)] {
        let a = Tensor::rand_uniform(&[m, k], -1.0, 1.0, &mut rng);
        let w = Tensor::rand_uniform(&[k, n], -1.0, 1.0, &mut rng);
        let flops = (2 * m * k * n) as f64;
        b.case_with_work(&format!("matmul {m}x{k}x{n}"), Some(flops), || {
            black_box(ops::matmul(&a, &w));
        });
    }

    // --- convolutions ---------------------------------------------------------
    let x = Tensor::rand_uniform(&[8, 16, 16, 8], -1.0, 1.0, &mut rng);
    let w = Tensor::rand_uniform(&[3, 3, 8, 16], -0.5, 0.5, &mut rng);
    let conv_flops = (8 * 16 * 16 * 16 * 2 * 3 * 3 * 8) as f64;
    b.case_with_work("conv2d 8x16x16x8 -> 16ch", Some(conv_flops), || {
        black_box(ops::conv2d(&x, &w, 1, true));
    });
    let dwf = Tensor::rand_uniform(&[3, 3, 8], -0.5, 0.5, &mut rng);
    b.case_with_work(
        "depthwise 8x16x16x8",
        Some((8 * 16 * 16 * 8 * 2 * 9) as f64),
        || {
            black_box(ops::depthwise_conv2d(&x, &dwf, 1, true));
        },
    );

    // --- workload inner loops -----------------------------------------------
    let tspec = twofc::TwoFcSpec::default();
    let step = twofc::train_step_graph(&tspec);
    let data = digits::generate(64, tspec.side(), 3);
    let batches = data.batches(tspec.batch);
    let init = twofc::TwoFcWeights::init(&tspec, 1);
    let step_flops = step.total_flops() as f64 * batches.len() as f64;
    b.case_with_work("2fcnet train 2 batches (graph interp)", Some(step_flops), || {
        black_box(twofc::run_training(&step, &init, &batches, 1));
    });

    let mspec = mobilenet::MobileNetSpec::default();
    let weights = coordinator::load_or_random_weights(&mspec, 1);
    let g = mobilenet::predict_graph(&mspec, &weights);
    let pdata = patterns::generate(32, mspec.side, 4);
    let fwd_flops = g.total_flops() as f64 * (32 / mspec.batch) as f64;
    b.case_with_work("mobilenet predict 32 samples (graph interp)", Some(fwd_flops), || {
        black_box(mobilenet::accuracy_on(&g, &mspec, &pdata));
    });

    // --- graph overheads ---------------------------------------------------------
    b.case("graph clone (train-step)", || {
        black_box(step.clone());
    });
    b.case("graph verify (train-step)", || {
        black_box(gevo_ml::ir::verify::verify(&step).unwrap());
    });
    b.case("total_flops (train-step)", || {
        black_box(step.total_flops());
    });
    b.finish();
}
