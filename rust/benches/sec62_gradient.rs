//! §6.2 regeneration: baseline vs Fig. 5 gradient-scale mutation vs the
//! paper's lr-0.3 verification, with training wall-clock per variant.

use gevo_ml::data::digits;
use gevo_ml::evo::search::Evaluator;
use gevo_ml::fitness::training::TrainingWorkload;
use gevo_ml::fitness::RuntimeMetric;
use gevo_ml::models::twofc;
use gevo_ml::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("sec62_gradient_mutation");
    b.samples = 3;
    b.warmup = 1;

    let spec = twofc::TwoFcSpec::default();
    let data = digits::generate(768, spec.side(), 7);
    let (fit, test) = data.split(576);
    let base = twofc::train_step_graph(&spec);
    let wl = TrainingWorkload::new(spec, &base, fit, test, 1, 1, RuntimeMetric::Flops);

    let mut fig5 = base.clone();
    twofc::apply_fig5_gradient_mutation(&mut fig5).expect("fig5 applies");
    let hi = twofc::TwoFcSpec { lr: 0.3, ..spec };
    let rows: Vec<(&str, gevo_ml::ir::Graph)> = vec![
        ("baseline lr=0.01", base.clone()),
        ("fig5-mutation", fig5),
        ("lr=0.3 verification", twofc::train_step_graph(&hi)),
    ];
    for (name, g) in rows {
        let obj = wl.evaluate(&g);
        let post = wl.post_hoc(&g);
        b.case(&format!("train 1 epoch [{name}]"), || {
            black_box(wl.evaluate(&g));
        });
        if let (Some((t, e)), Some((_, et))) = (obj, post) {
            b.note(&format!("  {name}: flops {t:.4}x train-err {e:.4} test-err {et:.4}"));
        }
    }
    b.note("paper: single Fig. 5 mutation = +4.88% training accuracy; lr 0.3 matches it");
    b.finish();
}
