//! §6.1 regeneration: the three key MobileNet mutations, singly and
//! jointly — runtime ratio (FLOPs + measured wall) and accuracy.

use gevo_ml::coordinator;
use gevo_ml::data::patterns;
use gevo_ml::models::mobilenet::{self, KeyMutation};
use gevo_ml::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("sec61_key_mutations");
    b.samples = 3;
    b.warmup = 1;

    let spec = mobilenet::MobileNetSpec::default();
    let weights = coordinator::load_or_random_weights(&spec, 1);
    let base = mobilenet::predict_graph(&spec, &weights);
    let data = patterns::generate(256, spec.side, 7);
    let base_flops = base.total_flops() as f64;

    let combos: Vec<(&str, Vec<KeyMutation>)> = vec![
        ("baseline", vec![]),
        ("bn-gamma-swap", vec![KeyMutation::BnGammaSwap]),
        ("drop-fc-bias", vec![KeyMutation::DropFcBias]),
        ("drop-last-conv", vec![KeyMutation::DropLastConv]),
        (
            "joint(all-three)",
            vec![KeyMutation::BnGammaSwap, KeyMutation::DropFcBias, KeyMutation::DropLastConv],
        ),
    ];
    for (name, muts) in combos {
        let mut g = base.clone();
        let applied = mobilenet::key_mutations(&mut g, &muts);
        let acc = mobilenet::accuracy_on(&g, &spec, &data);
        b.case(&format!("predict 256 samples [{name}]"), || {
            black_box(mobilenet::accuracy_on(&g, &spec, &data));
        });
        b.note(&format!(
            "  {name}: applied {applied}/{}  flops {:.4}x  acc {acc:.4}",
            muts.len(),
            g.total_flops() as f64 / base_flops
        ));
    }
    b.note("paper: individually weak, jointly ~1.9x faster at -2% accuracy (§6.1)");
    b.finish();
}
