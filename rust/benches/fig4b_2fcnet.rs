//! Fig. 4b regeneration (scaled): Pareto front for 2fcNet training.
//! Full-budget run: `cargo run --release --example evolve_2fcnet`.

use gevo_ml::coordinator::{self, ExperimentConfig, WorkloadKind};
use gevo_ml::evo::search::SearchConfig;
use gevo_ml::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig4b_2fcnet_training");
    b.samples = 1;
    b.warmup = 0;

    let cfg = ExperimentConfig {
        kind: WorkloadKind::TwoFcTraining,
        search: SearchConfig {
            pop_size: 16,
            generations: 8,
            elites: 8,
            seed: 42,
            verbose: false,
            ..Default::default()
        },
        fit_samples: 384,
        test_samples: 128,
        epochs: 1,
        ..Default::default()
    };
    let mut result = None;
    b.case("search pop=16 gens=8 (scaled Fig. 4b)", || {
        result = Some(coordinator::run_experiment(&cfg));
    });
    let r = result.unwrap();
    b.note(&format!(
        "baseline: runtime {:.4} error {:.4} (orange diamond)",
        r.baseline_fit.0, r.baseline_fit.1
    ));
    for (i, p) in r.front.iter().enumerate() {
        b.note(&format!(
            "front[{i}]: runtime {:.4} error {:.4} (edits {})",
            p.fit.0, p.fit.1, p.edits
        ));
    }
    let best = r
        .front
        .iter()
        .filter(|p| p.fit.0 <= r.baseline_fit.0 * 1.001)
        .map(|p| p.fit.1)
        .fold(f64::INFINITY, f64::min);
    b.note(&format!(
        "headline: paper error 8.62%->3.74% at equal runtime; ours {:.2}%->{:.2}%",
        r.baseline_fit.1 * 100.0,
        best * 100.0
    ));
    b.note(&format!("evaluations: {}", r.search.total_evaluations));
    b.finish();
}
