//! §Perf L3: evolutionary-machinery micro-benchmarks — mutation+repair
//! throughput, crossover, NSGA-II sorting, a full evaluated generation
//! (the end-to-end unit of search cost), the threaded island
//! runtime's generations/sec scaling at 1 vs N island threads, the
//! batched cohort engine's evals/sec at stacked widths 1/8/32, and the
//! telemetry subsystem's cost: the clock noise floor, the per-event
//! overhead of a `--trace` JSONL stream, the per-kernel-step price of
//! `--profile` hooks, and the spread reduction the noise-robust timing
//! harness buys for measured-time search, plus the serve daemon's
//! request-dispatch and submit-to-first-generation latency (summary
//! committed as `BENCH_evo.json`).

use gevo_ml::evo::crossover::messy_one_point;
use gevo_ml::evo::island::run_with_checkpoint;
use gevo_ml::evo::mutate::valid_random_edit;
use gevo_ml::evo::nsga2;
use gevo_ml::evo::patch::Individual;
use gevo_ml::evo::search::{self, Evaluator, SearchConfig};
use gevo_ml::exec::{BatchScratch, Program, Scratch};
use gevo_ml::ir::op::{OpKind, ReduceKind};
use gevo_ml::ir::types::TType;
use gevo_ml::ir::Graph;
use gevo_ml::models::twofc;
use gevo_ml::tensor::Tensor;
use gevo_ml::util::bench::{black_box, Bench};
use gevo_ml::util::json::Json;
use gevo_ml::util::rng::Rng;

/// An interpreter-backed workload heavy enough that island stepping (not
/// bench overhead) dominates: elementwise chains + reduce over 128×128.
fn island_workload() -> (Graph, impl Evaluator) {
    let mut g = Graph::new("bench");
    let x = g.param(TType::of(&[128, 128]));
    let e = g.push(OpKind::Exponential, &[x]).unwrap();
    let t = g.push(OpKind::Tanh, &[e]).unwrap();
    let m = g.push(OpKind::Multiply, &[t, x]).unwrap();
    let a = g.push(OpKind::Add, &[m, e]).unwrap();
    let r = g
        .push(OpKind::Reduce { dims: vec![0, 1], kind: ReduceKind::Sum }, &[a])
        .unwrap();
    g.set_outputs(&[r]);
    let base_flops = g.total_flops() as f64;
    let input = gevo_ml::tensor::Tensor::iota(&[128, 128]);
    let baseline = gevo_ml::interp::eval(&g, &[input.clone()]).unwrap()[0].item() as f64;
    let eval = move |vg: &Graph| -> Option<(f64, f64)> {
        let out = gevo_ml::interp::eval(vg, &[input.clone()]).ok()?;
        if out[0].has_non_finite() {
            return None;
        }
        let err = (out[0].item() as f64 - baseline).abs() / baseline.abs().max(1e-9);
        Some((vg.total_flops() as f64 / base_flops, err))
    };
    (g, eval)
}

fn main() {
    let mut b = Bench::new("perf_evo");
    let spec = twofc::TwoFcSpec { batch: 8, input: 36, hidden: 12, classes: 10, lr: 0.05 };
    let base = twofc::train_step_graph(&spec);

    // --- mutation + repair throughput ----------------------------------------
    let mut rng = Rng::new(3);
    b.case_with_work("valid_random_edit (x20)", Some(20.0), || {
        for _ in 0..20 {
            black_box(valid_random_edit(&base, &mut rng, 25));
        }
    });

    // --- crossover -------------------------------------------------------------
    let mut pool: Vec<Individual> = Vec::new();
    let mut prng = Rng::new(7);
    for _ in 0..16 {
        let mut ind = Individual::original();
        let mut g = base.clone();
        for _ in 0..3 {
            if let Some((e, ng)) = valid_random_edit(&g, &mut prng, 25) {
                ind.edits.push(e);
                g = ng;
            }
        }
        pool.push(ind);
    }
    b.case_with_work("messy crossover + materialize (x20)", Some(20.0), || {
        let mut r = Rng::new(11);
        for _ in 0..20 {
            let (c, _) = messy_one_point(&pool[r.below(16)], &pool[r.below(16)], &mut r);
            black_box(c.materialize(&base).ok());
        }
    });

    // --- NSGA-II --------------------------------------------------------------
    for n in [100usize, 1000] {
        let mut r = Rng::new(13);
        let pts: Vec<(f64, f64)> = (0..n).map(|_| (r.f64(), r.f64())).collect();
        b.case(&format!("non_dominated_sort n={n}"), || {
            black_box(nsga2::non_dominated_sort(&pts));
        });
        b.case(&format!("select_best n={n} k={}", n / 2), || {
            black_box(nsga2::select_best(&pts, n / 2));
        });
    }

    // --- a full generation ------------------------------------------------------
    let flops = base.total_flops() as f64;
    let eval = move |g: &gevo_ml::ir::Graph| -> Option<(f64, f64)> {
        Some((g.total_flops() as f64 / flops, 0.1))
    };
    let cfg = SearchConfig {
        pop_size: 16,
        generations: 1,
        elites: 8,
        workers: 2,
        seed: 5,
        verbose: false,
        ..Default::default()
    };
    b.case("one full generation (pop=16, flops-only eval)", || {
        black_box(search::run(&base, &eval, &cfg));
    });

    // --- threaded island runtime: generations/sec at 1 vs N threads -----------
    // The search is bit-identical at every `island_threads`, so the only
    // thing this section measures is wall clock. `workers: 1` keeps the
    // within-island evaluation serial — island threads are the sole
    // source of parallelism — and the per-thread summary is committed as
    // BENCH_evo.json so the perf trajectory has an artifact in CI.
    let (ig, ieval) = island_workload();
    let island_cfg = SearchConfig {
        pop_size: 8,
        generations: 6,
        elites: 4,
        workers: 1,
        seed: 17,
        islands: 4,
        migration_interval: 2,
        migrants: 1,
        verbose: false,
        ..Default::default()
    };
    let gens_total = (island_cfg.generations * island_cfg.islands) as f64;
    let mut rows: Vec<Json> = Vec::new();
    let mut p50_at_one = 0.0f64;
    for threads in [1usize, 2, 4] {
        let cfg = SearchConfig { island_threads: threads, ..island_cfg.clone() };
        let p50 = b.case_with_work(
            &format!("island search (K=4, gens=6, island_threads={threads})"),
            Some(gens_total),
            || {
                black_box(run_with_checkpoint(&ig, &ieval, &cfg, None));
            },
        );
        if threads == 1 {
            p50_at_one = p50;
        }
        let speedup = if p50 > 0.0 { p50_at_one / p50 } else { 0.0 };
        b.note(&format!(
            "island_threads={threads}: {:.1} gens/s, speedup {speedup:.2}x vs sequential",
            gens_total / p50.max(1e-12)
        ));
        rows.push(Json::obj(vec![
            ("island_threads", Json::num(threads as f64)),
            ("islands", Json::num(island_cfg.islands as f64)),
            ("generations", Json::num(island_cfg.generations as f64)),
            ("seconds_p50", Json::num(p50)),
            ("gens_per_sec", Json::num(gens_total / p50.max(1e-12))),
            ("speedup_vs_sequential", Json::num(speedup)),
        ]));
    }
    // --- batched cohort execution: evals/sec at width 1 vs 8 vs 32 ------------
    // Width 1 is the scalar `run_refs` path (genome-at-a-time baseline);
    // wider rows stack replicated input lanes through one `run_lanes`
    // call. Same compiled program, same inputs, bit-identical outputs —
    // the ratio is pure scheduling gain. Every row executes 32 lane
    // evaluations total so `evals_per_sec` is directly comparable.
    let prog = Program::compile(&base).expect("train step compiles");
    let mut irng = Rng::new(23);
    let inputs: Vec<Tensor> = base
        .param_types()
        .iter()
        .map(|t| Tensor::rand_uniform(&t.dims, 0.0, 1.0, &mut irng))
        .collect();
    let input_refs: Vec<&Tensor> = inputs.iter().collect();
    let mut batch_rows: Vec<Json> = Vec::new();
    let mut eps_at_one = 0.0f64;
    for width in [1usize, 8, 32] {
        let p50 = if width == 1 {
            let mut scratch = Scratch::new();
            b.case_with_work("batched eval width=1 (scalar run_refs, x32)", Some(32.0), || {
                for _ in 0..32 {
                    black_box(prog.run_refs(&input_refs, &mut scratch).unwrap());
                }
            })
        } else {
            let lanes: Vec<&[&Tensor]> =
                (0..width).map(|_| input_refs.as_slice()).collect();
            let reps = 32 / width;
            let mut scratch = BatchScratch::new();
            b.case_with_work(
                &format!("batched eval width={width} (run_lanes, x32 lanes)"),
                Some(32.0),
                || {
                    for _ in 0..reps {
                        black_box(prog.run_lanes(&lanes, &mut scratch));
                    }
                },
            )
        };
        let eps = 32.0 / p50.max(1e-12);
        if width == 1 {
            eps_at_one = eps;
        }
        let speedup = if eps_at_one > 0.0 { eps / eps_at_one } else { 0.0 };
        b.note(&format!(
            "batch width={width}: {eps:.1} evals/s, {speedup:.2}x vs scalar"
        ));
        batch_rows.push(Json::obj(vec![
            ("width", Json::num(width as f64)),
            ("seconds_p50", Json::num(p50)),
            ("evals_per_sec", Json::num(eps)),
            ("speedup_vs_scalar", Json::num(speedup)),
        ]));
    }

    // --- telemetry: clock noise floor + per-event trace overhead --------------
    // The noise floor bounds what a phase span can resolve; the trace
    // row prices `--trace` against the sequential island run above
    // (p50_at_one is the identical untraced configuration).
    let noise = gevo_ml::telemetry::timing_noise();
    b.note(&format!(
        "timing noise floor: median {:.0} ns, IQR {:.0} ns over {} empty spans",
        noise.median_ns, noise.iqr_ns, noise.samples
    ));
    let trace_path =
        std::env::temp_dir().join(format!("gevo_bench_trace_{}.jsonl", std::process::id()));
    let traced_cfg = SearchConfig {
        island_threads: 1,
        trace: Some(trace_path.clone()),
        ..island_cfg.clone()
    };
    let p50_traced = b.case_with_work(
        "island search (K=4, gens=6, --trace JSONL)",
        Some(gens_total),
        || {
            // the stream appends; start every iteration from an empty file
            let _ = std::fs::remove_file(&trace_path);
            black_box(run_with_checkpoint(&ig, &ieval, &traced_cfg, None));
        },
    );
    let trace_events = std::fs::read_to_string(&trace_path)
        .map(|t| t.lines().filter(|l| !l.trim().is_empty()).count())
        .unwrap_or(0);
    let _ = std::fs::remove_file(&trace_path);
    let ns_per_event = if trace_events > 0 {
        (p50_traced - p50_at_one).max(0.0) * 1e9 / trace_events as f64
    } else {
        0.0
    };
    b.note(&format!(
        "trace overhead: {trace_events} events/run, ~{ns_per_event:.0} ns/event (p50 delta vs untraced)"
    ));

    // --- profiler: per-step hook cost, on vs off -------------------------------
    // One probe run counts the kernel steps per execution; the same
    // 64-execution loop then runs through the plain and the profiled
    // entry points, so the p50 delta divided by total steps prices the
    // two clock reads per step that `--profile` adds.
    let mut probe_sink = gevo_ml::telemetry::ProfileSink::new();
    let mut probe_scratch = Scratch::new();
    black_box(prog.run_refs_profiled(&input_refs, &mut probe_scratch, &mut probe_sink).unwrap());
    let steps_per_run: f64 = probe_sink.rows().iter().map(|r| r.count).sum::<u64>() as f64;
    let mut off_scratch = Scratch::new();
    let p50_off = b.case_with_work("exec train step, profile hooks off (x64)", Some(64.0), || {
        for _ in 0..64 {
            black_box(prog.run_refs(&input_refs, &mut off_scratch).unwrap());
        }
    });
    let mut on_scratch = Scratch::new();
    let mut on_sink = gevo_ml::telemetry::ProfileSink::new();
    let p50_on = b.case_with_work("exec train step, profile hooks on (x64)", Some(64.0), || {
        for _ in 0..64 {
            black_box(
                prog.run_refs_profiled(&input_refs, &mut on_scratch, &mut on_sink).unwrap(),
            );
        }
    });
    let ns_per_step = (p50_on - p50_off).max(0.0) * 1e9 / (64.0 * steps_per_run.max(1.0));
    b.note(&format!(
        "profiler overhead: {steps_per_run:.0} kernel steps/run, ~{ns_per_step:.1} ns/step (p50 delta, hooks on vs off)"
    ));

    // --- timing harness: robust median vs single-shot spread -------------------
    // The same execution timed two ways: N raw one-shot spans (the old
    // `--metric wall` behavior) vs N harness measurements (warmup +
    // MAD-filtered median). The max-min spread ratio is the noise the
    // harness removes from measured-time search.
    let harness = gevo_ml::telemetry::TimingHarness::monotonic();
    let hreps = 12usize;
    let mut raw = Vec::with_capacity(hreps);
    let mut robust = Vec::with_capacity(hreps);
    let mut h_scratch = Scratch::new();
    for _ in 0..hreps {
        let t0 = std::time::Instant::now();
        black_box(prog.run_refs(&input_refs, &mut h_scratch).unwrap());
        raw.push(t0.elapsed().as_secs_f64());
    }
    for _ in 0..hreps {
        let m = harness
            .measure(|| prog.run_refs(&input_refs, &mut h_scratch).is_ok())
            .unwrap_or(0.0);
        robust.push(m);
    }
    let spread_ns = |xs: &[f64]| -> f64 {
        let mn = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let mx = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        ((mx - mn) * 1e9).max(0.0)
    };
    let raw_spread = spread_ns(&raw);
    let robust_spread = spread_ns(&robust);
    let spread_reduction = if robust_spread > 0.0 { raw_spread / robust_spread } else { 0.0 };
    b.note(&format!(
        "timing harness: single-shot spread {raw_spread:.0} ns vs robust-median spread \
         {robust_spread:.0} ns over {hreps} measurements ({spread_reduction:.1}x tighter)"
    ));

    // --- serve: request dispatch + submit-to-first-gen latency -----------------
    // The daemon must be cheap enough that polling a job's status never
    // competes with the search for meaningful time. Dispatch is a full
    // in-process round-trip (connect, parse, route, respond); the
    // latency row is wall clock from POST /jobs to the first published
    // generation of a deliberately tiny job.
    let serve_dir =
        std::env::temp_dir().join(format!("gevo_bench_serve_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&serve_dir);
    let handle = gevo_ml::serve::spawn(&gevo_ml::serve::ServeConfig {
        addr: "127.0.0.1:0".into(),
        state_dir: serve_dir.clone(),
        runners: 1,
        verbose: false,
    })
    .expect("serve daemon spawns");
    let addr = handle.addr;
    let roundtrip = move |method: &str, path: &str, body: &str| -> (u16, Json) {
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(addr).expect("connect");
        s.write_all(
            format!(
                "{method} {path} HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .expect("send");
        let mut buf = String::new();
        s.read_to_string(&mut buf).expect("recv");
        let status = buf
            .strip_prefix("HTTP/1.1 ")
            .and_then(|r| r.split(' ').next())
            .and_then(|c| c.parse().ok())
            .unwrap_or(0);
        let body = Json::parse(buf.split("\r\n\r\n").nth(1).unwrap_or("")).unwrap_or(Json::Null);
        (status, body)
    };
    let p50_dispatch = b.case_with_work("serve GET /healthz round-trip (x16)", Some(16.0), || {
        for _ in 0..16 {
            black_box(roundtrip("GET", "/healthz", ""));
        }
    });
    let dispatch_ns = p50_dispatch * 1e9 / 16.0;
    b.note(&format!("serve dispatch: ~{dispatch_ns:.0} ns per request round-trip"));
    let t0 = std::time::Instant::now();
    let (status, resp) = roundtrip(
        "POST",
        "/jobs",
        r#"{"workload":"2fcnet","generations":1,"fit":32,"test":16,"workers":1,
            "config":{"pop_size":4,"elites":2,"init_mutations":1,"max_tries":5}}"#,
    );
    assert_eq!(status, 201, "bench job submit failed: {resp:?}");
    let job_id = resp.get("id").unwrap().as_usize().unwrap();
    let submit_to_first_gen = loop {
        let (_, st) = roundtrip("GET", &format!("/jobs/{job_id}"), "");
        let completed = st.opt("completed").and_then(|c| c.as_usize().ok()).unwrap_or(0);
        let done = st.opt("state").and_then(|s| s.as_str().ok().map(str::to_string))
            == Some("done".into());
        if completed >= 1 || done {
            break t0.elapsed().as_secs_f64();
        }
        assert!(
            t0.elapsed().as_secs() < 60,
            "bench job never reached its first generation: {st:?}"
        );
        std::thread::sleep(std::time::Duration::from_millis(2));
    };
    b.note(&format!(
        "serve submit-to-first-gen: {:.1} ms (pop=4, fit=32 2fcNet job)",
        submit_to_first_gen * 1e3
    ));
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&serve_dir);

    let summary = Json::obj(vec![
        ("suite", Json::str("perf_evo")),
        ("section", Json::str("threaded-island-runtime+batched-eval+telemetry+serve")),
        ("island_scaling", Json::Arr(rows)),
        ("batch_scaling", Json::Arr(batch_rows)),
        (
            "timing_noise",
            Json::obj(vec![
                ("samples", Json::num(noise.samples as f64)),
                ("median_ns", Json::num(noise.median_ns)),
                ("iqr_ns", Json::num(noise.iqr_ns)),
            ]),
        ),
        (
            "trace_overhead",
            Json::obj(vec![
                ("events_per_run", Json::num(trace_events as f64)),
                ("seconds_p50_untraced", Json::num(p50_at_one)),
                ("seconds_p50_traced", Json::num(p50_traced)),
                ("ns_per_event", Json::num(ns_per_event)),
            ]),
        ),
        (
            "profiler_overhead",
            Json::obj(vec![
                ("steps_per_run", Json::num(steps_per_run)),
                ("seconds_p50_off", Json::num(p50_off)),
                ("seconds_p50_on", Json::num(p50_on)),
                ("ns_per_step", Json::num(ns_per_step)),
            ]),
        ),
        (
            "timing_harness",
            Json::obj(vec![
                ("measurements", Json::num(hreps as f64)),
                ("warmup", Json::num(harness.warmup as f64)),
                ("samples_per_measurement", Json::num(harness.samples as f64)),
                ("single_shot_spread_ns", Json::num(raw_spread)),
                ("robust_median_spread_ns", Json::num(robust_spread)),
                ("spread_reduction", Json::num(spread_reduction)),
            ]),
        ),
        (
            "serve_overhead",
            Json::obj(vec![
                ("dispatch_ns", Json::num(dispatch_ns)),
                ("submit_to_first_gen_seconds", Json::num(submit_to_first_gen)),
            ]),
        ),
        (
            "provenance",
            Json::str(
                "generated by `cargo bench --bench perf_evo`; the committed file is a \
                 structural baseline — CI regenerates it and checks sections, not \
                 absolute timings",
            ),
        ),
    ]);
    std::fs::write("BENCH_evo.json", summary.to_pretty())
        .expect("write BENCH_evo.json");
    b.note("wrote BENCH_evo.json");
    b.finish();
}
