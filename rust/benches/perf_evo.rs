//! §Perf L3: evolutionary-machinery micro-benchmarks — mutation+repair
//! throughput, crossover, NSGA-II sorting, and a full evaluated
//! generation (the end-to-end unit of search cost).

use gevo_ml::evo::crossover::messy_one_point;
use gevo_ml::evo::mutate::valid_random_edit;
use gevo_ml::evo::nsga2;
use gevo_ml::evo::patch::Individual;
use gevo_ml::evo::search::{self, SearchConfig};
use gevo_ml::models::twofc;
use gevo_ml::util::bench::{black_box, Bench};
use gevo_ml::util::rng::Rng;

fn main() {
    let mut b = Bench::new("perf_evo");
    let spec = twofc::TwoFcSpec { batch: 8, input: 36, hidden: 12, classes: 10, lr: 0.05 };
    let base = twofc::train_step_graph(&spec);

    // --- mutation + repair throughput ----------------------------------------
    let mut rng = Rng::new(3);
    b.case_with_work("valid_random_edit (x20)", Some(20.0), || {
        for _ in 0..20 {
            black_box(valid_random_edit(&base, &mut rng, 25));
        }
    });

    // --- crossover -------------------------------------------------------------
    let mut pool: Vec<Individual> = Vec::new();
    let mut prng = Rng::new(7);
    for _ in 0..16 {
        let mut ind = Individual::original();
        let mut g = base.clone();
        for _ in 0..3 {
            if let Some((e, ng)) = valid_random_edit(&g, &mut prng, 25) {
                ind.edits.push(e);
                g = ng;
            }
        }
        pool.push(ind);
    }
    b.case_with_work("messy crossover + materialize (x20)", Some(20.0), || {
        let mut r = Rng::new(11);
        for _ in 0..20 {
            let (c, _) = messy_one_point(&pool[r.below(16)], &pool[r.below(16)], &mut r);
            black_box(c.materialize(&base).ok());
        }
    });

    // --- NSGA-II --------------------------------------------------------------
    for n in [100usize, 1000] {
        let mut r = Rng::new(13);
        let pts: Vec<(f64, f64)> = (0..n).map(|_| (r.f64(), r.f64())).collect();
        b.case(&format!("non_dominated_sort n={n}"), || {
            black_box(nsga2::non_dominated_sort(&pts));
        });
        b.case(&format!("select_best n={n} k={}", n / 2), || {
            black_box(nsga2::select_best(&pts, n / 2));
        });
    }

    // --- a full generation ------------------------------------------------------
    let flops = base.total_flops() as f64;
    let eval = move |g: &gevo_ml::ir::Graph| -> Option<(f64, f64)> {
        Some((g.total_flops() as f64 / flops, 0.1))
    };
    let cfg = SearchConfig {
        pop_size: 16,
        generations: 1,
        elites: 8,
        workers: 2,
        seed: 5,
        verbose: false,
        ..Default::default()
    };
    b.case("one full generation (pop=16, flops-only eval)", || {
        black_box(search::run(&base, &eval, &cfg));
    });
    b.finish();
}
