//! Table 1 regeneration: model layer composition of both workloads, plus
//! graph-construction/census timing.

use gevo_ml::models::{mobilenet, twofc};
use gevo_ml::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("table1_composition");

    let mspec = mobilenet::MobileNetSpec::default();
    let weights = mobilenet::random_weights(&mspec, 1);
    let tspec = twofc::TwoFcSpec::default();

    b.case("build mobilenet predict graph", || {
        black_box(mobilenet::predict_graph(&mspec, &weights));
    });
    b.case("build 2fcnet train-step graph", || {
        black_box(twofc::train_step_graph(&tspec));
    });

    let mg = mobilenet::predict_graph(&mspec, &weights);
    let tg = twofc::predict_graph(&tspec);
    b.case("census (table-1 rows)", || {
        black_box(mobilenet::table1_census(&mg));
        black_box(tg.census());
    });

    b.note("--- Table 1 (paper layout; reproduction-scale models) ---");
    b.note(&format!("{:<28} {:>10} {:>8}", "Layer", "MobileNet", "2fcNet"));
    let t_census = tg.census();
    for (name, count) in mobilenet::table1_census(&mg) {
        let t = if name == "Fully-connected Layer" {
            *t_census.get("dot").unwrap_or(&0)
        } else {
            0
        };
        b.note(&format!("{name:<28} {count:>9}x {t:>7}x"));
    }
    b.note(&format!(
        "paper reference (full scale): 17x dw-conv, 35x std-conv, 52x BN, 1x pool, 2x fc / 2x fc"
    ));
    b.note(&format!(
        "flops/batch: mobilenet {:.2}M, 2fcnet(predict) {:.2}M, 2fcnet(train-step) {:.2}M",
        mg.total_flops() as f64 / 1e6,
        tg.total_flops() as f64 / 1e6,
        twofc::train_step_graph(&tspec).total_flops() as f64 / 1e6
    ));
    b.finish();
}
