//! §4.2 statistic regeneration: "about 80% of the time" messy-crossover
//! offspring are valid. We measure the validity rate over random
//! populations of mutated individuals on the 2fcNet train graph.

use gevo_ml::evo::crossover::messy_one_point;
use gevo_ml::evo::mutate::valid_random_edit;
use gevo_ml::evo::patch::Individual;
use gevo_ml::models::twofc;
use gevo_ml::util::bench::Bench;
use gevo_ml::util::rng::Rng;

fn main() {
    let mut b = Bench::new("crossover_validity");
    b.samples = 1;
    b.warmup = 0;

    let spec = twofc::TwoFcSpec { batch: 8, input: 36, hidden: 12, classes: 10, lr: 0.05 };
    let base = twofc::train_step_graph(&spec);
    let mut rng = Rng::new(17);

    // build a pool of individuals with 1-4 valid edits each
    let mut pool: Vec<Individual> = Vec::new();
    for _ in 0..24 {
        let mut ind = Individual::original();
        let mut g = base.clone();
        let k = rng.range(1, 5);
        for _ in 0..k {
            if let Some((e, ng)) = valid_random_edit(&g, &mut rng, 25) {
                ind.edits.push(e);
                g = ng;
            }
        }
        pool.push(ind);
    }

    let mut valid = 0usize;
    let mut total = 0usize;
    b.case("1000 messy crossovers + materialize", || {
        valid = 0;
        total = 0;
        let mut r = Rng::new(99);
        for _ in 0..500 {
            let a = &pool[r.below(pool.len())];
            let bb = &pool[r.below(pool.len())];
            let (c, d) = messy_one_point(a, bb, &mut r);
            for child in [c, d] {
                total += 1;
                if child.materialize(&base).is_ok() {
                    valid += 1;
                }
            }
        }
    });
    b.note(&format!(
        "validity: {valid}/{total} = {:.1}%   (paper §4.2: ~80%)",
        100.0 * valid as f64 / total as f64
    ));
    b.finish();
}
