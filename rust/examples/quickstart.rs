//! Quickstart — the end-to-end driver proving all layers compose:
//!
//! 1. load the JAX-AOT HLO artifacts and execute them through PJRT (the
//!    Layer-2/Layer-1 compile path feeding the Layer-3 runtime);
//! 2. build the same 2fcNet training workload in the Rust IR, train it on
//!    the synthetic digit corpus, and report accuracy;
//! 3. run a short GEVO-ML search over the training graph and print the
//!    runtime/error Pareto front;
//! 4. cross-validate one Pareto survivor on real XLA via the IR→HLO
//!    emitter.
//!
//! Run: `cargo run --release --example quickstart`

use gevo_ml::coordinator::{self, report, ExperimentConfig, WorkloadKind};
use gevo_ml::data::digits;
use gevo_ml::evo::search::SearchConfig;
use gevo_ml::models::twofc;
use gevo_ml::runtime::{artifact::ArtifactDir, PjrtRuntime};
use gevo_ml::tensor::Tensor;
use gevo_ml::util::rng::Rng;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    println!("== GEVO-ML quickstart ==\n");

    // ---- 1. AOT artifacts through PJRT --------------------------------------
    // PJRT requires the `pjrt` cargo feature (vendored `xla` crate); the
    // quickstart degrades to the in-tree engines without it.
    let rt = match PjrtRuntime::cpu() {
        Ok(rt) => {
            println!("[1] PJRT platform: {}", rt.platform());
            Some(rt)
        }
        Err(e) => {
            println!("[1] PJRT unavailable ({e}); skipping XLA cross-checks");
            None
        }
    };
    match (&rt, ArtifactDir::load("artifacts")) {
        (Some(rt), Ok(art)) => {
            let e = art.get("twofc_predict")?;
            let exe = rt.compile_file(e.hlo_path.to_str().unwrap(), e.num_outputs)?;
            let mut rng = Rng::new(1);
            let inputs: Vec<Tensor> = e
                .input_shapes
                .iter()
                .map(|s| Tensor::rand_uniform(s, 0.0, 1.0, &mut rng))
                .collect();
            let out = exe.run(&inputs)?;
            println!(
                "    twofc_predict artifact: executed, output {:?} (rows sum to {:.4})",
                out[0].dims(),
                (0..10).map(|c| out[0].at(&[0, c])).sum::<f32>()
            );
        }
        (Some(_), Err(e)) => println!("    (no artifacts: {e}; run `make artifacts` first)"),
        (None, _) => {}
    }

    // ---- 2. the training workload in the Rust IR ----------------------------
    let spec = twofc::TwoFcSpec::default();
    let step = twofc::train_step_graph(&spec);
    let predict = twofc::predict_graph(&spec);
    println!(
        "\n[2] 2fcNet train-step graph: {} instructions, {:.2} MFLOP/step",
        step.len(),
        step.total_flops() as f64 / 1e6
    );
    let data = digits::generate(1024, spec.side(), 7);
    let (train, test) = data.split(768);
    let init = twofc::TwoFcWeights::init(&spec, 1);
    let batches = train.batches(spec.batch);
    let (w, loss) = twofc::run_training(&step, &init, &batches, 2).expect("training runs");
    println!(
        "    trained 2 epochs: loss {loss:.4}, train acc {:.4}, test acc {:.4}",
        twofc::accuracy_on(&predict, &spec, &w, &train),
        twofc::accuracy_on(&predict, &spec, &w, &test)
    );

    // ---- 3. a short GEVO-ML search -------------------------------------------
    println!("\n[3] GEVO-ML search (small budget — see evolve_2fcnet for the real run)");
    let cfg = ExperimentConfig {
        kind: WorkloadKind::TwoFcTraining,
        search: SearchConfig {
            pop_size: 12,
            generations: 4,
            elites: 6,
            seed: 42,
            verbose: false,
            ..Default::default()
        },
        fit_samples: 256,
        test_samples: 96,
        epochs: 1,
        ..Default::default()
    };
    let r = coordinator::run_experiment(&cfg);
    println!("{}", report::ascii_scatter(&r, 56, 12));
    println!("{}", report::front_markdown(&r));

    // ---- 4. cross-validate a survivor: compiled engine (and XLA if built) ---
    let base = twofc::train_step_graph(&spec);
    if let Some((ind, obj)) = r.search.pareto.first() {
        let g = ind.materialize(&base).expect("front survivor materializes");
        let mut rng = Rng::new(5);
        let inputs: Vec<Tensor> = g
            .param_types()
            .iter()
            .map(|t| Tensor::rand_uniform(&t.dims, 0.0, 1.0, &mut rng))
            .collect();
        let want = gevo_ml::interp::eval(&g, &inputs)?;
        let prog = gevo_ml::exec::Program::compile(&g)?;
        let got = prog.run(&inputs)?;
        // bitwise comparison (NaN-safe): mutants are often numerically
        // broken, and both engines must be broken identically
        let identical = want.iter().zip(got.iter()).all(|(a, b)| {
            a.dims() == b.dims()
                && a.data()
                    .iter()
                    .zip(b.data().iter())
                    .all(|(x, y)| x.to_bits() == y.to_bits())
        });
        println!(
            "[4] Pareto survivor (runtime {:.4}, error {:.4}): compiled engine {} interpreter",
            obj.0,
            obj.1,
            if identical { "==" } else { "!=" }
        );
        if let Some(rt) = &rt {
            let got = rt.compile_graph(&g)?.run(&inputs)?;
            let agree = want.iter().zip(got.iter()).all(|(a, b)| a.allclose(b, 1e-3));
            println!("    XLA cross-check: {}", if agree { "==" } else { "!=" });
        }
    }
    println!("\nquickstart OK");
    Ok(())
}
