//! §6.1 / §6.2 — mutation analysis: reconstruct the paper's key
//! discovered mutations and measure their individual and joint effects.
//!
//! * `--model mobilenet` — the three epistatic MobileNet mutations
//!   (BN-γ swap, drop fc bias, drop last conv), applied singly and
//!   jointly (§6.1).
//! * `--model 2fcnet` — the single Fig. 5 gradient-scale mutation
//!   (pad/slice of the labels replacing the 1/32 constant), plus the
//!   paper's learning-rate verification (§6.2).
//!
//! Run: `cargo run --release --example mutation_analysis -- --model 2fcnet`

use gevo_ml::coordinator;
use gevo_ml::data::{digits, patterns};
use gevo_ml::evo::search::Evaluator;
use gevo_ml::fitness::training::TrainingWorkload;
use gevo_ml::fitness::RuntimeMetric;
use gevo_ml::models::mobilenet::{self, KeyMutation};
use gevo_ml::models::twofc;
use gevo_ml::util::cli::Args;
use std::time::Instant;

fn main() {
    let args = Args::parse_env(false);
    match args.get_or("model", "2fcnet").as_str() {
        "mobilenet" => mobilenet_61(&args),
        _ => twofc_62(&args),
    }
}

fn mobilenet_61(args: &Args) {
    let spec = mobilenet::MobileNetSpec::default();
    let weights = coordinator::load_or_random_weights(&spec, 1);
    let base = mobilenet::predict_graph(&spec, &weights);
    let n = args.usize_or("samples", 512);
    let data = patterns::generate(n, spec.side, 7);
    let base_flops = base.total_flops() as f64;

    let t0 = Instant::now();
    let base_acc = mobilenet::accuracy_on(&base, &spec, &data);
    let base_wall = t0.elapsed().as_secs_f64();

    println!("§6.1 — MobileNet prediction: key-mutation analysis ({n} samples)");
    println!("baseline: acc {base_acc:.4}  flops {:.2}M  wall {base_wall:.3}s\n", base_flops / 1e6);
    println!(
        "{:<44} {:>8} {:>9} {:>9} {:>9}",
        "mutation set", "applied", "flops", "wall", "acc"
    );
    let combos: Vec<(&str, Vec<KeyMutation>)> = vec![
        ("bn-gamma-swap (γ from prior BN)", vec![KeyMutation::BnGammaSwap]),
        ("drop-fc-bias", vec![KeyMutation::DropFcBias]),
        ("drop-last-conv", vec![KeyMutation::DropLastConv]),
        (
            "joint: all three (epistatic set of §6.1)",
            vec![KeyMutation::BnGammaSwap, KeyMutation::DropFcBias, KeyMutation::DropLastConv],
        ),
    ];
    for (name, muts) in combos {
        let mut g = base.clone();
        let applied = mobilenet::key_mutations(&mut g, &muts);
        let t0 = Instant::now();
        let acc = mobilenet::accuracy_on(&g, &spec, &data);
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{name:<44} {applied:>8} {:>8.4}x {:>8.4}x {acc:>9.4}",
            g.total_flops() as f64 / base_flops,
            wall / base_wall
        );
    }
    println!(
        "\nnote: our MobileNet-lite is ~13x shallower than the paper's 52-conv model,\n\
         so dropping the last conv costs more accuracy here; the epistasis signal\n\
         (joint runtime cut ≥ sum of parts, paper §6.1) is the reproduced shape."
    );
}

fn twofc_62(args: &Args) {
    let spec = twofc::TwoFcSpec::default();
    let n = args.usize_or("samples", 1024);
    let epochs = args.usize_or("epochs", 1);
    let data = digits::generate(n, spec.side(), 7);
    let (fit, test) = data.split(n * 3 / 4);
    let base = twofc::train_step_graph(&spec);
    let wl = TrainingWorkload::new(spec, &base, fit, test, epochs, 1, RuntimeMetric::Flops);

    println!(
        "§6.2 — 2fcNet training: the Fig. 5 gradient-scale mutation ({} samples, {} epoch(s), lr={})",
        n, epochs, spec.lr
    );
    println!(
        "\n{:<44} {:>9} {:>11} {:>11}",
        "variant", "flops", "train err", "test err"
    );

    let mut fig5 = base.clone();
    twofc::apply_fig5_gradient_mutation(&mut fig5).expect("Fig. 5 mutation applies");
    let hi = twofc::TwoFcSpec { lr: 0.3, ..spec };
    let rows: Vec<(&str, gevo_ml::ir::Graph)> = vec![
        ("baseline (grad x 1/32, lr 0.01)", base.clone()),
        ("Fig. 5 mutation (pad/slice labels -> ~1s)", fig5),
        ("lr 0.01 -> 0.3 (paper's §6.2 verification)", twofc::train_step_graph(&hi)),
    ];
    for (name, g) in rows {
        match (wl.evaluate(&g), wl.post_hoc(&g)) {
            (Some((t, e)), Some((_, et))) => {
                println!("{name:<44} {t:>8.4}x {e:>11.4} {et:>11.4}")
            }
            _ => println!("{name:<44} {:>9} {:>11} {:>11}", "-", "invalid", "-"),
        }
    }
    println!(
        "\npaper:  the single Fig. 5 mutation raised training accuracy by 4.88%\n\
        (error 8.62% -> 3.74%), and lr 0.01 -> 0.3 reproduced the same effect.\n\
        The reproduction shows the same shape: the pad/slice/constant-swap edit\n\
        enlarges the gradient ~batch-size-fold and matches the lr-boost run."
    );
}
