//! Fig. 4b — the 2fcNet *training* experiment: GEVO-ML searches the SGD
//! train-step graph for runtime/error Pareto improvements. The paper's
//! headline: +4.88% training accuracy (error 8.62% → 3.74%) at unchanged
//! runtime, via the gradient-scale mutation of §6.2/Fig. 5.
//!
//! Run: `cargo run --release --example evolve_2fcnet -- [--pop 32] [--gens 12] [--seed 42]
//!       [--islands 4] [--island-threads 4] [--migration-interval 4] [--checkpoint ck.json]
//!       [--opt-level 0|1|2|3] [--operators copy,delete,swap,replace,perturb] [--adapt]
//!       [--filter-neutral] [--reseed-minimized]`
//!
//! `--island-threads` steps islands on parallel OS threads between
//! migration barriers — bit-identical results, faster wall clock.

use gevo_ml::coordinator::{self, report, ExperimentConfig, WorkloadKind};
use gevo_ml::evo::search::SearchConfig;
use gevo_ml::util::cli::Args;

fn main() {
    let args = Args::parse_env(false);
    let cfg = ExperimentConfig {
        kind: WorkloadKind::TwoFcTraining,
        search: SearchConfig {
            pop_size: args.usize_or("pop", 32),
            generations: args.usize_or("gens", 12),
            elites: args.usize_or("elites", 16),
            seed: args.u64_or("seed", 42),
            workers: args.usize_or(
                "workers",
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            ),
            islands: args.usize_or("islands", 1),
            island_threads: args.usize_or("island-threads", 1),
            migration_interval: args.usize_or("migration-interval", 4),
            migrants: args.usize_or("migrants", 2),
            opt_level: gevo_ml::opt::OptLevel::parse(&args.get_or("opt-level", "2"))
                .expect("--opt-level must be 0, 1, 2 or 3"),
            operators: match args.get("operators") {
                None => gevo_ml::evo::operators::default_names(),
                Some(list) => gevo_ml::evo::operators::parse_cli_list(list)
                    .unwrap_or_else(|e| panic!("--operators: {e}")),
            },
            adapt: args.flag("adapt"),
            filter_neutral: args.flag("filter-neutral"),
            reseed_minimized: args.flag("reseed-minimized"),
            verbose: !args.flag("quiet"),
            ..Default::default()
        },
        fit_samples: args.usize_or("fit", 512),
        test_samples: args.usize_or("test", 160),
        epochs: args.usize_or("epochs", 1),
        checkpoint: args.get("checkpoint").map(std::path::PathBuf::from),
        ..Default::default()
    };
    eprintln!(
        "Fig. 4b reproduction: 2fcNet training, pop={} gens={}",
        cfg.search.pop_size, cfg.search.generations
    );
    let r = coordinator::run_experiment(&cfg);
    println!("{}", report::ascii_scatter(&r, 64, 16));
    println!("{}", report::front_markdown(&r));

    // Paper headline: best error at ≤ baseline runtime.
    let base_err = r.baseline_fit.1;
    let best = r
        .front
        .iter()
        .filter(|p| p.fit.0 <= r.baseline_fit.0 * 1.001)
        .map(|p| p.fit.1)
        .fold(f64::INFINITY, f64::min);
    println!(
        "\npaper:   training error  8.62% -> 3.74% (+4.88% accuracy) at equal runtime"
    );
    println!(
        "ours:    training error {:.2}% -> {:.2}% ({:+.2}% accuracy) at equal runtime",
        base_err * 100.0,
        best * 100.0,
        (base_err - best) * 100.0
    );
    println!(
        "evaluations: {}   cache hits: {}   wall: {:.1}s",
        r.search.total_evaluations, r.search.cache_hits, r.wall_seconds
    );
    if r.search.islands.len() > 1 {
        print!("{}", report::island_summary(&r));
    }
    if cfg.search.adapt || cfg.search.operators != gevo_ml::evo::operators::default_names() {
        println!("{}", report::operator_markdown(&r));
    }
    if let Some(f) = r.search.program_fusion {
        println!("{}", report::fusion_summary(&f));
    }
    if let Some(prefix) = args.get("out") {
        std::fs::write(format!("{prefix}.json"), report::to_json(&r).to_pretty()).unwrap();
        std::fs::write(format!("{prefix}.csv"), report::front_csv(&r)).unwrap();
    }
}
