//! §6.2 verification — the learning-rate sweep: "We verified this
//! assumption by increasing the learning rate from 0.01 to 0.3 and
//! achieve comparable accuracy improvement." This example sweeps lr and
//! reports train/test error next to the Fig. 5 mutation's effect, so the
//! equivalence claim can be eyeballed.
//!
//! Run: `cargo run --release --example lr_sweep`

use gevo_ml::data::digits;
use gevo_ml::evo::search::Evaluator;
use gevo_ml::fitness::training::TrainingWorkload;
use gevo_ml::fitness::RuntimeMetric;
use gevo_ml::models::twofc;
use gevo_ml::util::cli::Args;

fn main() {
    let args = Args::parse_env(false);
    let n = args.usize_or("samples", 1024);
    let epochs = args.usize_or("epochs", 1);
    let spec0 = twofc::TwoFcSpec::default();
    let data = digits::generate(n, spec0.side(), 7);
    let (fit, test) = data.split(n * 3 / 4);
    let base = twofc::train_step_graph(&spec0);
    let wl = TrainingWorkload::new(spec0, &base, fit, test, epochs, 1, RuntimeMetric::Flops);

    println!("§6.2 learning-rate sweep ({n} samples, {epochs} epoch(s))\n");
    println!("{:<28} {:>11} {:>11}", "variant", "train err", "test err");
    for lr in [0.01f32, 0.03, 0.1, 0.2, 0.3, 0.5, 1.0] {
        let spec = twofc::TwoFcSpec { lr, ..spec0 };
        let g = twofc::train_step_graph(&spec);
        match (wl.evaluate(&g), wl.post_hoc(&g)) {
            (Some((_, e)), Some((_, et))) => {
                println!("lr = {lr:<24} {e:>11.4} {et:>11.4}")
            }
            _ => println!("lr = {lr:<24} {:>11} {:>11}", "diverged", "-"),
        }
    }
    let mut fig5 = base.clone();
    twofc::apply_fig5_gradient_mutation(&mut fig5).expect("fig5 applies");
    if let (Some((_, e)), Some((_, et))) = (wl.evaluate(&fig5), wl.post_hoc(&fig5)) {
        println!("{:<28} {e:>11.4} {et:>11.4}", "Fig. 5 mutation (≈ lr x32)");
    }
}
