//! Integration: island-model sharding and checkpoint/resume over the full
//! search stack — determinism across runs, bit-identical resume, and the
//! `islands = 1` ≡ single-population equivalence guarantee.

use gevo_ml::evo::island::run_with_checkpoint;
use gevo_ml::evo::nsga2::Objectives;
use gevo_ml::evo::search::{self, Evaluator, SearchConfig, SearchResult};
use gevo_ml::ir::op::{OpKind, ReduceKind};
use gevo_ml::ir::types::TType;
use gevo_ml::ir::Graph;

/// The toy workload from the search unit tests: runtime = normalized
/// FLOPs, error = |output − baseline| on one input.
fn toy() -> (Graph, impl Evaluator) {
    let mut g = Graph::new("toy");
    let x = g.param(TType::of(&[4, 4]));
    let e1 = g.push(OpKind::Exponential, &[x]).unwrap();
    let t = g.push(OpKind::Tanh, &[e1]).unwrap();
    let a = g.push(OpKind::Add, &[t, x]).unwrap();
    let r = g
        .push(OpKind::Reduce { dims: vec![0, 1], kind: ReduceKind::Sum }, &[a])
        .unwrap();
    g.set_outputs(&[r]);
    let base_flops = g.total_flops() as f64;
    let input = gevo_ml::tensor::Tensor::iota(&[4, 4]);
    let baseline = gevo_ml::interp::eval(&g, &[input.clone()]).unwrap()[0].item() as f64;
    let eval = move |vg: &Graph| -> Option<Objectives> {
        let out = gevo_ml::interp::eval(vg, &[input.clone()]).ok()?;
        if out[0].has_non_finite() {
            return None;
        }
        let err = (out[0].item() as f64 - baseline).abs() / baseline.abs().max(1e-9);
        let time = vg.total_flops() as f64 / base_flops;
        Some((time, err))
    };
    (g, eval)
}

fn front_of(r: &SearchResult) -> Vec<Objectives> {
    r.pareto.iter().map(|(_, o)| *o).collect()
}

/// Unique scratch path per test; best-effort cleanup wrapper.
struct TempCk(std::path::PathBuf);

impl TempCk {
    fn new(tag: &str) -> TempCk {
        TempCk(std::env::temp_dir().join(format!("gevo_ck_{tag}_{}.json", std::process::id())))
    }
}

impl Drop for TempCk {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

#[test]
fn islands_one_is_the_plain_single_population_search() {
    let (g, eval) = toy();
    let cfg = SearchConfig {
        pop_size: 8,
        generations: 3,
        elites: 4,
        workers: 1,
        seed: 9,
        islands: 1,
        ..Default::default()
    };
    let plain = search::run(&g, &eval, &cfg);
    let via_islands = run_with_checkpoint(&g, &eval, &cfg, None);
    assert_eq!(front_of(&plain), front_of(&via_islands));
    assert_eq!(plain.total_evaluations, via_islands.total_evaluations);
    assert!(via_islands.pareto_islands.iter().all(|&i| i == 0));
    assert_eq!(via_islands.migrations, 0, "a lone island must never migrate");
}

#[test]
fn island_runs_are_seed_deterministic() {
    let (g, eval) = toy();
    let cfg = SearchConfig {
        pop_size: 6,
        generations: 4,
        elites: 3,
        workers: 1,
        seed: 21,
        islands: 3,
        migration_interval: 2,
        migrants: 2,
        ..Default::default()
    };
    let a = run_with_checkpoint(&g, &eval, &cfg, None);
    let b = run_with_checkpoint(&g, &eval, &cfg, None);
    assert_eq!(front_of(&a), front_of(&b), "same seed must reproduce the merged front");
    assert_eq!(a.pareto_islands, b.pareto_islands, "provenance must be deterministic too");
    assert_eq!(a.total_evaluations, b.total_evaluations);
    assert_eq!(a.migrations, b.migrations);
    assert_eq!(a.islands.len(), 3);
    // every generation produced one stats row per island
    assert_eq!(a.history.len(), 4 * 3);
}

#[test]
fn islands_explore_more_than_one_stream() {
    let (g, eval) = toy();
    let one = SearchConfig {
        pop_size: 6,
        generations: 3,
        elites: 3,
        workers: 1,
        seed: 33,
        islands: 1,
        ..Default::default()
    };
    let many = SearchConfig { islands: 3, migration_interval: 2, ..one.clone() };
    let a = run_with_checkpoint(&g, &eval, &one, None);
    let b = run_with_checkpoint(&g, &eval, &many, None);
    assert!(
        b.total_evaluations > a.total_evaluations,
        "three islands must evaluate more than one ({} vs {})",
        b.total_evaluations,
        a.total_evaluations
    );
}

#[test]
fn resume_from_checkpoint_reproduces_uninterrupted_run() {
    let (g, eval) = toy();
    let cfg = SearchConfig {
        pop_size: 6,
        generations: 5,
        elites: 3,
        workers: 1,
        seed: 13,
        islands: 2,
        migration_interval: 2,
        migrants: 1,
        ..Default::default()
    };
    let uninterrupted = run_with_checkpoint(&g, &eval, &cfg, None);

    // "kill" after two generations, then resume towards the full target
    let ck = TempCk::new("resume");
    let partial_cfg = SearchConfig { generations: 2, ..cfg.clone() };
    let partial = run_with_checkpoint(&g, &eval, &partial_cfg, Some(&ck.0));
    assert!(ck.0.exists(), "checkpoint file must be written");
    assert!(partial.history.len() < uninterrupted.history.len());
    let resumed = run_with_checkpoint(&g, &eval, &cfg, Some(&ck.0));

    assert_eq!(
        front_of(&uninterrupted),
        front_of(&resumed),
        "resumed run must reproduce the uninterrupted Pareto front exactly"
    );
    assert_eq!(uninterrupted.pareto_islands, resumed.pareto_islands);
    assert_eq!(uninterrupted.total_evaluations, resumed.total_evaluations);
    assert_eq!(uninterrupted.cache_hits, resumed.cache_hits);
    assert_eq!(uninterrupted.migrations, resumed.migrations);
    assert_eq!(uninterrupted.history.len(), resumed.history.len());
    for (a, b) in uninterrupted.history.iter().zip(resumed.history.iter()) {
        assert_eq!((a.gen, a.island, a.evaluated, a.valid), (b.gen, b.island, b.evaluated, b.valid));
        assert_eq!(a.best_time.to_bits(), b.best_time.to_bits());
        assert_eq!(a.best_error.to_bits(), b.best_error.to_bits());
    }
}

#[test]
fn resuming_a_finished_run_is_a_no_op() {
    let (g, eval) = toy();
    let cfg = SearchConfig {
        pop_size: 6,
        generations: 3,
        elites: 3,
        workers: 1,
        seed: 17,
        ..Default::default()
    };
    let ck = TempCk::new("finished");
    let first = run_with_checkpoint(&g, &eval, &cfg, Some(&ck.0));
    let again = run_with_checkpoint(&g, &eval, &cfg, Some(&ck.0));
    assert_eq!(front_of(&first), front_of(&again));
    assert_eq!(first.total_evaluations, again.total_evaluations);
    assert_eq!(first.history.len(), again.history.len());
}

#[test]
#[should_panic(expected = "mismatch")]
fn resuming_with_a_different_seed_is_rejected() {
    let (g, eval) = toy();
    let cfg = SearchConfig {
        pop_size: 6,
        generations: 2,
        elites: 3,
        workers: 1,
        seed: 40,
        ..Default::default()
    };
    let ck = TempCk::new("mismatch");
    run_with_checkpoint(&g, &eval, &cfg, Some(&ck.0));
    let other = SearchConfig { seed: 41, ..cfg };
    run_with_checkpoint(&g, &eval, &other, Some(&ck.0));
}
