//! Integration: IR → HLO text → real XLA (PJRT CPU) must agree with the
//! in-tree interpreter — the contract that lets the search validate its
//! Pareto-front survivors on a production compiler (DESIGN.md §1).
//!
//! Compiled only with the `pjrt` cargo feature (requires a vendored `xla`
//! crate; the offline registry carries none). The interpreter-vs-compiled
//! contract is covered offline by `tests/exec_differential.rs`.
#![cfg(feature = "pjrt")]

use gevo_ml::interp::eval;
use gevo_ml::ir::op::{OpKind, ReduceKind};
use gevo_ml::ir::types::TType;
use gevo_ml::ir::Graph;
use gevo_ml::runtime::PjrtRuntime;
use gevo_ml::tensor::Tensor;
use gevo_ml::util::rng::Rng;

fn check_graph(g: &Graph, inputs: &[Tensor], atol: f32) {
    gevo_ml::ir::verify::verify(g).expect("graph verifies");
    let want = eval(g, inputs).expect("interpreter eval");
    let rt = PjrtRuntime::cpu().expect("pjrt client");
    let exe = rt
        .compile_graph(g)
        .unwrap_or_else(|e| panic!("XLA rejected emitted HLO:\n{e:?}\n{}", gevo_ml::ir::hlo_emit::emit(g)));
    let got = exe.run(inputs).expect("pjrt run");
    assert_eq!(want.len(), got.len());
    for (i, (w, g_)) in want.iter().zip(got.iter()).enumerate() {
        assert_eq!(w.dims(), g_.dims(), "output {i} shape");
        assert!(
            w.allclose(g_, atol),
            "output {i} differs: max |Δ| = {}",
            w.max_abs_diff(g_)
        );
    }
}

#[test]
fn elementwise_chain() {
    let mut g = Graph::new("ew");
    let x = g.param(TType::of(&[2, 3]));
    let y = g.param(TType::of(&[2, 3]));
    let a = g.push(OpKind::Add, &[x, y]).unwrap();
    let m = g.push(OpKind::Multiply, &[a, x]).unwrap();
    let e = g.push(OpKind::Exponential, &[m]).unwrap();
    let t = g.push(OpKind::Tanh, &[e]).unwrap();
    let n = g.push(OpKind::Negate, &[t]).unwrap();
    g.set_outputs(&[n]);
    let mut rng = Rng::new(1);
    let xs = Tensor::rand_uniform(&[2, 3], -1.0, 1.0, &mut rng);
    let ys = Tensor::rand_uniform(&[2, 3], -1.0, 1.0, &mut rng);
    check_graph(&g, &[xs, ys], 1e-5);
}

#[test]
fn dot_broadcast_reduce() {
    let mut g = Graph::new("dbr");
    let x = g.param(TType::of(&[4, 3]));
    let w = g.param(TType::of(&[3, 5]));
    let b = g.param(TType::of(&[5]));
    let d = g.push(OpKind::Dot, &[x, w]).unwrap();
    let bb = g
        .push(OpKind::Broadcast { dims: vec![4, 5], mapping: vec![1] }, &[b])
        .unwrap();
    let a = g.push(OpKind::Add, &[d, bb]).unwrap();
    let s = g
        .push(OpKind::Reduce { dims: vec![1], kind: ReduceKind::Sum }, &[a])
        .unwrap();
    let mx = g
        .push(OpKind::Reduce { dims: vec![0], kind: ReduceKind::Max }, &[a])
        .unwrap();
    g.set_outputs(&[a, s, mx]);
    let mut rng = Rng::new(2);
    check_graph(
        &g,
        &[
            Tensor::rand_uniform(&[4, 3], -1.0, 1.0, &mut rng),
            Tensor::rand_uniform(&[3, 5], -1.0, 1.0, &mut rng),
            Tensor::rand_uniform(&[5], -1.0, 1.0, &mut rng),
        ],
        1e-4,
    );
}

#[test]
fn pad_slice_transpose_reshape_concat() {
    let mut g = Graph::new("shapes");
    let x = g.param(TType::of(&[2, 3]));
    let p = g
        .push(OpKind::Pad { low: vec![1, 0], high: vec![0, 2], value: 1.0 }, &[x])
        .unwrap();
    let s = g
        .push(OpKind::Slice { starts: vec![0, 1], limits: vec![3, 4] }, &[p])
        .unwrap();
    let t = g.push(OpKind::Transpose { perm: vec![1, 0] }, &[s]).unwrap();
    let r = g.push(OpKind::Reshape { dims: vec![9] }, &[t]).unwrap();
    let c = g.push(OpKind::Concat { dim: 0 }, &[r, r]).unwrap();
    g.set_outputs(&[c]);
    let mut rng = Rng::new(3);
    check_graph(&g, &[Tensor::rand_uniform(&[2, 3], -2.0, 2.0, &mut rng)], 1e-6);
}

#[test]
fn constants_select_compare() {
    let mut g = Graph::new("csc");
    let x = g.param(TType::of(&[3, 3]));
    let c = g.constant(Tensor::iota(&[3, 3]));
    let gt = g.push(OpKind::CompareGt, &[x, c]).unwrap();
    let sel = g.push(OpKind::Select, &[gt, x, c]).unwrap();
    g.set_outputs(&[gt, sel]);
    let mut rng = Rng::new(4);
    check_graph(&g, &[Tensor::rand_uniform(&[3, 3], 0.0, 9.0, &mut rng)], 1e-6);
}

#[test]
fn conv_and_depthwise_and_pool() {
    let mut g = Graph::new("convs");
    let x = g.param(TType::of(&[2, 6, 6, 3]));
    let w = g.param(TType::of(&[3, 3, 3, 4]));
    let dw = g.param(TType::of(&[3, 3, 4]));
    let c = g.push(OpKind::Conv2d { stride: 2, same: true }, &[x, w]).unwrap();
    let d = g
        .push(OpKind::DepthwiseConv2d { stride: 1, same: true }, &[c, dw])
        .unwrap();
    let p = g.push(OpKind::GlobalAvgPool, &[d]).unwrap();
    g.set_outputs(&[c, d, p]);
    let mut rng = Rng::new(5);
    check_graph(
        &g,
        &[
            Tensor::rand_uniform(&[2, 6, 6, 3], -1.0, 1.0, &mut rng),
            Tensor::rand_uniform(&[3, 3, 3, 4], -0.5, 0.5, &mut rng),
            Tensor::rand_uniform(&[3, 3, 4], -0.5, 0.5, &mut rng),
        ],
        1e-4,
    );
}

#[test]
fn softmax_like_paper_fig1() {
    // The Fig. 1 tail: reduce_max / subtract / exp / reduce_sum / divide.
    let mut g = Graph::new("softmax");
    let x = g.param(TType::of(&[4, 7]));
    let m = g
        .push(OpKind::Reduce { dims: vec![1], kind: ReduceKind::Max }, &[x])
        .unwrap();
    let mb = g
        .push(OpKind::Broadcast { dims: vec![4, 7], mapping: vec![0] }, &[m])
        .unwrap();
    let sub = g.push(OpKind::Subtract, &[x, mb]).unwrap();
    let ex = g.push(OpKind::Exponential, &[sub]).unwrap();
    let s = g
        .push(OpKind::Reduce { dims: vec![1], kind: ReduceKind::Sum }, &[ex])
        .unwrap();
    let sb = g
        .push(OpKind::Broadcast { dims: vec![4, 7], mapping: vec![0] }, &[s])
        .unwrap();
    let out = g.push(OpKind::Divide, &[ex, sb]).unwrap();
    g.set_outputs(&[out]);
    let mut rng = Rng::new(6);
    check_graph(&g, &[Tensor::rand_uniform(&[4, 7], -3.0, 3.0, &mut rng)], 1e-5);
}

#[test]
fn resize_chain_compiles_on_xla() {
    // The §4.1 repair chain (reshape+slice+pad) must be XLA-compilable,
    // since repaired variants are validated post hoc via PJRT.
    let mut g = Graph::new("resize");
    let x = g.param(TType::of(&[3, 4, 4]));
    let (v, _, n) =
        gevo_ml::ir::resize::resize_chain(&mut g, 1, x, &TType::of(&[2, 2])).unwrap();
    assert!(n >= 2);
    g.set_outputs(&[v]);
    let mut rng = Rng::new(7);
    check_graph(&g, &[Tensor::rand_uniform(&[3, 4, 4], -1.0, 1.0, &mut rng)], 1e-6);
}

#[test]
fn scalar_inputs_and_outputs() {
    let mut g = Graph::new("scalar");
    let x = g.param(TType::scalar());
    let y = g.push(OpKind::Sqrt, &[x]).unwrap();
    let c = g.constant_scalar(2.0);
    let z = g.push(OpKind::Multiply, &[y, c]).unwrap();
    g.set_outputs(&[z]);
    check_graph(&g, &[Tensor::scalar(12.25)], 1e-6);
}
