//! Property tests at the workload level: every valid mutation/crossover
//! product preserves the graph contract the fitness layer relies on
//! (signature stability, executability, finiteness checks).

use gevo_ml::evo::crossover::messy_one_point;
use gevo_ml::evo::mutate::valid_random_edit;
use gevo_ml::evo::patch::Individual;
use gevo_ml::ir::verify::verify;
use gevo_ml::models::{mobilenet, twofc};
use gevo_ml::tensor::Tensor;
use gevo_ml::util::prop::run_prop;
use gevo_ml::util::rng::Rng;

fn twofc_base() -> gevo_ml::ir::Graph {
    let spec = twofc::TwoFcSpec { batch: 4, input: 16, hidden: 8, classes: 4, lr: 0.1 };
    twofc::train_step_graph(&spec)
}

#[test]
fn prop_mutations_preserve_signature_on_train_graph() {
    let base = twofc_base();
    let out_tys = base.output_types();
    let in_tys = base.param_types();
    run_prop(40, 0xAB1, |rng| {
        if let Some((_, g)) = valid_random_edit(&base, rng, 25) {
            if g.output_types() != out_tys {
                return Err("output signature changed".into());
            }
            if g.param_types() != in_tys {
                return Err("input signature changed".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mutation_chains_stay_valid_and_executable() {
    let base = twofc_base();
    run_prop(20, 0xAB2, |rng| {
        let mut ind = Individual::original();
        let mut g = base.clone();
        for _ in 0..rng.range(1, 5) {
            if let Some((e, ng)) = valid_random_edit(&g, rng, 25) {
                ind.edits.push(e);
                g = ng;
            }
        }
        let m = ind
            .materialize(&base)
            .map_err(|e| format!("materialize failed: {e}"))?;
        verify(&m).map_err(|e| format!("verify failed: {e}"))?;
        let inputs: Vec<Tensor> = m
            .param_types()
            .iter()
            .map(|t| Tensor::rand_uniform(&t.dims, 0.0, 1.0, rng))
            .collect();
        gevo_ml::interp::eval(&m, &inputs).map_err(|e| format!("exec failed: {e}"))?;
        Ok(())
    });
}

#[test]
fn prop_crossover_products_valid_or_cleanly_rejected() {
    let base = twofc_base();
    run_prop(15, 0xAB3, |rng| {
        let mut mk = |rng: &mut Rng| {
            let mut ind = Individual::original();
            let mut g = base.clone();
            for _ in 0..3 {
                if let Some((e, ng)) = valid_random_edit(&g, rng, 25) {
                    ind.edits.push(e);
                    g = ng;
                }
            }
            ind
        };
        let a = mk(rng);
        let b = mk(rng);
        let (c, d) = messy_one_point(&a, &b, rng);
        for child in [c, d] {
            // materialize either succeeds with a verified graph or fails
            // with an error — never panics, never returns a broken graph
            if let Ok(g) = child.materialize(&base) {
                verify(&g).map_err(|e| format!("crossover child invalid: {e}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mobilenet_mutations_execute() {
    let spec = mobilenet::MobileNetSpec { batch: 2, side: 8, classes: 4, width: 4, blocks: 2 };
    let w = mobilenet::random_weights(&spec, 3);
    let base = mobilenet::predict_graph(&spec, &w);
    run_prop(15, 0xAB4, |rng| {
        if let Some((_, g)) = valid_random_edit(&base, rng, 25) {
            let inputs = vec![Tensor::rand_uniform(&[2, 8, 8, 3], 0.0, 1.0, rng)];
            let out = gevo_ml::interp::eval(&g, &inputs)
                .map_err(|e| format!("exec failed: {e}"))?;
            if out[0].dims() != [2, 4] {
                return Err(format!("wrong output shape {:?}", out[0].dims()));
            }
        }
        Ok(())
    });
}
