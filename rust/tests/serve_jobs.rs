//! Integration: the `gevo-ml serve` daemon end to end, over real
//! sockets (ISSUE 10 acceptance).
//!
//! * a job submitted over HTTP finishes with a Pareto front
//!   bit-identical to a direct `run_experiment` of the same config;
//! * kill the daemon mid-run, restart it on the same state dir, and the
//!   resumed job's front AND final checkpoint bytes match an
//!   uninterrupted run;
//! * two jobs running concurrently on shared runners don't
//!   cross-contaminate;
//! * a malformed submit is a 400 and leaves zero residue in the state
//!   dir.

use gevo_ml::coordinator::{report, run_experiment};
use gevo_ml::serve::jobs::parse_spec;
use gevo_ml::serve::{spawn, ServeConfig};
use gevo_ml::util::json::Json;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// State dir that cleans up after itself (and before, if a previous
/// aborted run left debris).
struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        let dir =
            std::env::temp_dir().join(format!("gevo_serve_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        TempDir(dir)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn daemon(dir: &Path, runners: usize) -> gevo_ml::serve::ServerHandle {
    spawn(&ServeConfig {
        addr: "127.0.0.1:0".into(),
        state_dir: dir.to_path_buf(),
        runners,
        verbose: false,
    })
    .expect("daemon spawns")
}

/// One HTTP exchange: returns (status, parsed JSON body).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, Json) {
    let mut s = TcpStream::connect(addr).expect("connect");
    let raw = format!(
        "{method} {path} HTTP/1.1\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(raw.as_bytes()).expect("send");
    let mut buf = String::new();
    s.read_to_string(&mut buf).expect("recv");
    let status: u16 = buf
        .strip_prefix("HTTP/1.1 ")
        .and_then(|r| r.split(' ').next())
        .and_then(|c| c.parse().ok())
        .unwrap_or_else(|| panic!("malformed response: {buf:?}"));
    let text = buf.split("\r\n\r\n").nth(1).unwrap_or("");
    let json = Json::parse(text).unwrap_or(Json::Null);
    (status, json)
}

fn submit(addr: SocketAddr, spec: &str) -> u64 {
    let (status, body) = http(addr, "POST", "/jobs", spec);
    assert_eq!(status, 201, "submit failed: {body:?}");
    body.get("id").unwrap().as_usize().unwrap() as u64
}

/// Poll until the job reaches a terminal state; panic on `failed` or
/// after the deadline.
fn wait_done(addr: SocketAddr, id: u64) -> Json {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (status, body) = http(addr, "GET", &format!("/jobs/{id}"), "");
        assert_eq!(status, 200, "status poll failed: {body:?}");
        match body.get("state").unwrap().as_str().unwrap() {
            "done" => return body,
            "failed" => panic!("job {id} failed: {body:?}"),
            _ => {}
        }
        assert!(Instant::now() < deadline, "job {id} did not finish: {body:?}");
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Poll until the job has completed at least `gens` generations.
fn wait_progress(addr: SocketAddr, id: u64, gens: usize) {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (_, body) = http(addr, "GET", &format!("/jobs/{id}"), "");
        let completed = body.get("completed").unwrap().as_usize().unwrap();
        let state = body.get("state").unwrap().as_str().unwrap().to_string();
        if completed >= gens || state == "done" {
            return;
        }
        assert_ne!(state, "failed", "job {id} failed: {body:?}");
        assert!(Instant::now() < deadline, "job {id} stalled at {completed}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// A small-but-real 2fcNet spec; `seed` varies per scenario so tests
/// never share checkpoint-compatible configs by accident.
fn spec(generations: usize, seed: u64) -> String {
    format!(
        r#"{{"workload":"2fcnet","generations":{generations},"fit":64,"test":32,"workers":2,
            "config":{{"seed":{seed},"pop_size":6,"elites":3,"init_mutations":2,"max_tries":10}}}}"#
    )
}

/// The bit-identity oracle: run the exact same config through the
/// plain coordinator path.
fn direct_front(spec_text: &str, checkpoint: Option<&Path>) -> (Json, Json) {
    let mut cfg = parse_spec(&Json::parse(spec_text).unwrap()).unwrap();
    cfg.checkpoint = checkpoint.map(Path::to_path_buf);
    let r = run_experiment(&cfg);
    let j = report::to_json(&r);
    (j.get("front").unwrap().clone(), j.get("baseline_fit").unwrap().clone())
}

#[test]
fn served_front_is_bit_identical_to_direct_run() {
    let dir = TempDir::new("direct");
    let handle = daemon(&dir.0, 1);
    let spec_text = spec(3, 101);
    let id = submit(handle.addr, &spec_text);
    wait_done(handle.addr, id);

    let (status, served) = http(handle.addr, "GET", &format!("/jobs/{id}/front"), "");
    assert_eq!(status, 200);
    let (front, baseline) = direct_front(&spec_text, None);
    assert_eq!(served.get("front").unwrap(), &front, "served front must be bit-identical");
    assert_eq!(served.get("baseline_fit").unwrap(), &baseline);
    assert_eq!(served.get("workload").unwrap().as_str().unwrap(), "2fcnet");
    handle.shutdown();
}

#[test]
fn killed_daemon_resumes_job_bit_identically() {
    let dir = TempDir::new("resume");
    let spec_text = spec(8, 202);

    // first daemon: get the job at least one generation in, then shut
    // down — the stop lands at a barrier with the checkpoint written and
    // the durable record still saying "running"
    let first = daemon(&dir.0, 1);
    let id = submit(first.addr, &spec_text);
    wait_progress(first.addr, id, 1);
    first.shutdown();

    // second daemon on the same state dir: the job rescans as queued
    // (or finished, if the stop raced past the last generation) and
    // resumes from its checkpoint
    let second = daemon(&dir.0, 1);
    wait_done(second.addr, id);
    let (_, served) = http(second.addr, "GET", &format!("/jobs/{id}/front"), "");
    second.shutdown();

    // oracle: the same config run uninterrupted, with a checkpoint of
    // its own for byte comparison
    let oracle_ck = dir.0.join("oracle.ck.json");
    let (front, _) = direct_front(&spec_text, Some(&oracle_ck));
    assert_eq!(
        served.get("front").unwrap(),
        &front,
        "front after kill+resume must equal the uninterrupted run"
    );
    let job_ck = std::fs::read(dir.0.join(format!("job-{id}.ck.json"))).unwrap();
    let oracle_bytes = std::fs::read(&oracle_ck).unwrap();
    assert_eq!(
        job_ck, oracle_bytes,
        "final checkpoint bytes must equal the uninterrupted run's"
    );
}

#[test]
fn concurrent_jobs_do_not_cross_contaminate() {
    let dir = TempDir::new("pair");
    let handle = daemon(&dir.0, 2);
    let spec_a = spec(3, 303);
    let spec_b = spec(3, 404);
    let a = submit(handle.addr, &spec_a);
    let b = submit(handle.addr, &spec_b);
    wait_done(handle.addr, a);
    wait_done(handle.addr, b);
    let (_, front_a) = http(handle.addr, "GET", &format!("/jobs/{a}/front"), "");
    let (_, front_b) = http(handle.addr, "GET", &format!("/jobs/{b}/front"), "");
    handle.shutdown();

    let (want_a, _) = direct_front(&spec_a, None);
    let (want_b, _) = direct_front(&spec_b, None);
    assert_eq!(front_a.get("front").unwrap(), &want_a, "job A contaminated");
    assert_eq!(front_b.get("front").unwrap(), &want_b, "job B contaminated");
}

#[test]
fn malformed_submit_is_rejected_without_residue() {
    let dir = TempDir::new("reject");
    let handle = daemon(&dir.0, 1);
    for bad in [
        "this is not json",
        r#"{"workload":"resnet"}"#,
        r#"{"workload":"2fcnet","bogus_knob":1}"#,
        r#"{"workload":"2fcnet","config":{"pop":8}}"#,
        r#"{"generations":3}"#,
    ] {
        let (status, body) = http(handle.addr, "POST", "/jobs", bad);
        assert_eq!(status, 400, "{bad:?} should be rejected, got {body:?}");
        assert!(body.get("error").is_ok(), "400 body should carry an error message");
    }
    let (_, listing) = http(handle.addr, "GET", "/jobs", "");
    assert!(listing.get("jobs").unwrap().as_arr().unwrap().is_empty());
    let residue: Vec<String> = std::fs::read_dir(&dir.0)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    assert!(residue.is_empty(), "rejected submits left files: {residue:?}");
    handle.shutdown();
}
