//! Integration: the full search stack over the real workloads (small
//! budgets) — Pareto-dominance invariants, determinism, workload wiring.

use gevo_ml::coordinator::{self, ExperimentConfig, WorkloadKind};
use gevo_ml::evo::nsga2::dominates;
use gevo_ml::evo::search::SearchConfig;

fn tiny(kind: WorkloadKind, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        kind,
        search: SearchConfig {
            pop_size: 8,
            generations: 3,
            elites: 4,
            workers: 2,
            seed,
            verbose: false,
            ..Default::default()
        },
        fit_samples: 96,
        test_samples: 32,
        epochs: 1,
        ..Default::default()
    }
}

#[test]
fn training_front_is_mutually_nondominated() {
    let r = coordinator::run_experiment(&tiny(WorkloadKind::TwoFcTraining, 1));
    assert!(!r.front.is_empty());
    for (i, a) in r.front.iter().enumerate() {
        for (j, b) in r.front.iter().enumerate() {
            if i != j {
                assert!(
                    !dominates(a.fit, b.fit),
                    "front members {i} and {j} dominate each other"
                );
            }
        }
    }
}

#[test]
fn front_never_dominated_by_baseline() {
    let r = coordinator::run_experiment(&tiny(WorkloadKind::TwoFcTraining, 2));
    for p in &r.front {
        assert!(
            !dominates(r.baseline_fit, p.fit),
            "baseline dominates front point {:?}",
            p.fit
        );
    }
}

#[test]
fn prediction_workload_runs_end_to_end() {
    let r = coordinator::run_experiment(&tiny(WorkloadKind::MobilenetPrediction, 3));
    assert!(!r.front.is_empty());
    assert!((r.baseline_fit.0 - 1.0).abs() < 1e-9, "flops metric baseline = 1.0");
    // every front point has sane objective ranges
    for p in &r.front {
        assert!(p.fit.0 >= 0.0 && p.fit.0 < 10.0);
        assert!((0.0..=1.0).contains(&p.fit.1));
    }
}

#[test]
fn experiments_are_seed_deterministic() {
    let a = coordinator::run_experiment(&tiny(WorkloadKind::TwoFcTraining, 7));
    let b = coordinator::run_experiment(&tiny(WorkloadKind::TwoFcTraining, 7));
    let fa: Vec<_> = a.front.iter().map(|p| p.fit).collect();
    let fb: Vec<_> = b.front.iter().map(|p| p.fit).collect();
    assert_eq!(fa, fb, "same seed must reproduce the same front");
}

#[test]
fn different_seeds_explore_differently() {
    let a = coordinator::run_experiment(&tiny(WorkloadKind::TwoFcTraining, 10));
    let b = coordinator::run_experiment(&tiny(WorkloadKind::TwoFcTraining, 11));
    // not a hard guarantee, but with these budgets the evaluation counts
    // or fronts essentially always differ; treat equality of both as a bug
    let same_front = a.front.iter().map(|p| p.fit).collect::<Vec<_>>()
        == b.front.iter().map(|p| p.fit).collect::<Vec<_>>();
    let same_evals = a.search.total_evaluations == b.search.total_evaluations;
    assert!(!(same_front && same_evals), "two seeds produced identical searches");
}
