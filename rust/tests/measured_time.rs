//! Integration: per-kernel profiling and the noise-robust timing
//! harness over a *real compiled workload* (the 2fcNet training
//! evaluator). Pins the two ISSUE acceptance properties:
//!
//! * `--profile` is strictly observational — fronts, history and
//!   checkpoint bytes are bit-identical with it on or off, while the
//!   profiled run additionally surfaces non-empty per-kernel rows and
//!   `"profile"` trace events;
//! * `--metric wall` (and `blend`) under an injected deterministic
//!   [`FixedStepClock`] reproduces the same front bit-for-bit across
//!   independent runs, because every harness measurement collapses to
//!   exactly one clock step.

use gevo_ml::data::digits;
use gevo_ml::evo::island::run_with_checkpoint;
use gevo_ml::evo::search::{SearchConfig, SearchResult};
use gevo_ml::fitness::training::TrainingWorkload;
use gevo_ml::fitness::RuntimeMetric;
use gevo_ml::ir::Graph;
use gevo_ml::models::twofc::{self, TwoFcSpec};
use gevo_ml::telemetry::{FixedStepClock, TimingHarness};
use gevo_ml::util::json::Json;
use std::path::PathBuf;
use std::sync::Arc;

fn workload(metric: RuntimeMetric) -> (Graph, TrainingWorkload) {
    let spec = TwoFcSpec { batch: 8, input: 36, hidden: 8, classes: 10, lr: 0.2 };
    let step = twofc::train_step_graph(&spec);
    let data = digits::generate(96, spec.side(), 7);
    let (fit, test) = data.split(64);
    let wl = TrainingWorkload::new(spec, &step, fit, test, 1, 1, metric);
    (step, wl)
}

fn cfg(seed: u64) -> SearchConfig {
    SearchConfig {
        pop_size: 6,
        generations: 3,
        elites: 3,
        workers: 1,
        seed,
        islands: 2,
        migration_interval: 2,
        migrants: 1,
        island_threads: 1,
        checkpoint_every: 1,
        ..Default::default()
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("gevo_measured_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn fingerprint(r: &SearchResult) -> Vec<(u64, u64)> {
    r.pareto.iter().map(|(_, o)| (o.0.to_bits(), o.1.to_bits())).collect()
}

fn assert_history_bits_equal(a: &SearchResult, b: &SearchResult, label: &str) {
    assert_eq!(a.history.len(), b.history.len(), "{label}: history length");
    for (x, y) in a.history.iter().zip(b.history.iter()) {
        assert_eq!(x.best_time.to_bits(), y.best_time.to_bits(), "{label}: best_time bits");
        assert_eq!(x.best_error.to_bits(), y.best_error.to_bits(), "{label}: best_error bits");
    }
}

#[test]
fn profiling_a_compiled_workload_is_observational_and_fills_rows() {
    let dir = tmp_dir("profwl");
    let ck_off = dir.join("off.json");
    let ck_on = dir.join("on.json");
    let trace = dir.join("trace.jsonl");
    let (step, wl_off) = workload(RuntimeMetric::Flops);
    let (_, wl_on) = workload(RuntimeMetric::Flops);
    let base = cfg(23);
    let off = run_with_checkpoint(&step, &wl_off, &base, Some(&ck_off));
    let on = run_with_checkpoint(
        &step,
        &wl_on,
        &SearchConfig { profile: true, trace: Some(trace.clone()), ..base.clone() },
        Some(&ck_on),
    );
    assert_eq!(fingerprint(&off), fingerprint(&on), "front bits diverged under --profile");
    assert_eq!(off.total_evaluations, on.total_evaluations, "evaluations");
    assert_history_bits_equal(&off, &on, "--profile");
    assert_eq!(
        std::fs::read(&ck_off).unwrap(),
        std::fs::read(&ck_on).unwrap(),
        "checkpoint bytes diverged under --profile"
    );

    // The unprofiled run reports nothing; the profiled run reports
    // per-kernel rows with real accumulated time, dot among them (the
    // train step is dominated by matmuls).
    assert!(off.profile.is_none(), "profile rows must be opt-in");
    let rows = on.profile.expect("profiled compiled workload must report rows");
    assert!(!rows.is_empty(), "profiled run recorded no kernel steps");
    assert!(rows.iter().any(|r| r.kernel == "dot" && r.count > 0), "{rows:?}");
    let total: u64 = rows.iter().map(|r| r.total_ns).sum();
    assert!(total > 0, "profiled time must accumulate");

    // And the trace stream carries "profile" events for the analyzer.
    let text = std::fs::read_to_string(&trace).unwrap();
    let has_profile_event = text
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).unwrap())
        .any(|e| e.get("kind").unwrap().as_str().unwrap() == "profile");
    assert!(has_profile_event, "no profile event in the trace stream");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn wall_and_blend_search_under_fixed_clock_reproduces_fronts_bitwise() {
    for metric in [RuntimeMetric::WallClock, RuntimeMetric::Blend] {
        let mk = || {
            let (step, wl) = workload(metric);
            let wl = wl.with_timing(
                TimingHarness::with_clock(Arc::new(FixedStepClock::new(1_000))),
                &step,
            );
            (step, wl)
        };
        let base = cfg(31);
        let (step, wl_a) = mk();
        let (_, wl_b) = mk();
        let a = run_with_checkpoint(&step, &wl_a, &base, None);
        let b = run_with_checkpoint(&step, &wl_b, &base, None);
        assert_eq!(fingerprint(&a), fingerprint(&b), "{metric:?}: front bits diverged");
        assert_eq!(a.total_evaluations, b.total_evaluations, "{metric:?}: evaluations");
        assert_history_bits_equal(&a, &b, "fixed clock");
        if metric == RuntimeMetric::WallClock {
            // Every measured span is exactly one 1000ns clock step, so
            // every surviving front point has the same exact runtime.
            let want = (1_000.0f64 / 1e9).to_bits();
            assert!(
                a.pareto.iter().all(|(_, o)| o.0.to_bits() == want),
                "wall objectives must all be exactly one clock step"
            );
        }
    }
}
