//! Integration: the threaded island runtime over the public API —
//! bit-identity with the sequential schedule, kill/resume through the
//! async durable checkpoint writer, and survival of a panicking
//! evaluation worker.

use gevo_ml::evo::island::run_with_checkpoint;
use gevo_ml::evo::nsga2::Objectives;
use gevo_ml::evo::search::{Evaluator, SearchConfig, SearchResult};
use gevo_ml::ir::op::{OpKind, ReduceKind};
use gevo_ml::ir::types::TType;
use gevo_ml::ir::Graph;

/// The toy workload from the island unit tests: runtime = normalized
/// FLOPs, error = |output − baseline| on one input.
fn toy() -> (Graph, impl Evaluator) {
    let mut g = Graph::new("toy");
    let x = g.param(TType::of(&[4, 4]));
    let e1 = g.push(OpKind::Exponential, &[x]).unwrap();
    let t = g.push(OpKind::Tanh, &[e1]).unwrap();
    let a = g.push(OpKind::Add, &[t, x]).unwrap();
    let r = g
        .push(OpKind::Reduce { dims: vec![0, 1], kind: ReduceKind::Sum }, &[a])
        .unwrap();
    g.set_outputs(&[r]);
    let base_flops = g.total_flops() as f64;
    let input = gevo_ml::tensor::Tensor::iota(&[4, 4]);
    let baseline = gevo_ml::interp::eval(&g, &[input.clone()]).unwrap()[0].item() as f64;
    let eval = move |vg: &Graph| -> Option<Objectives> {
        let out = gevo_ml::interp::eval(vg, &[input.clone()]).ok()?;
        if out[0].has_non_finite() {
            return None;
        }
        let err = (out[0].item() as f64 - baseline).abs() / baseline.abs().max(1e-9);
        let time = vg.total_flops() as f64 / base_flops;
        Some((time, err))
    };
    (g, eval)
}

/// Everything observable about a search outcome that must be
/// schedule-independent, with objectives as exact bit patterns.
/// (Program-cache *performance* counters are deliberately excluded:
/// racing compiles of one key legitimately vary with scheduling.)
fn fingerprint(r: &SearchResult) -> (Vec<(u64, u64)>, Vec<usize>, usize, usize, usize) {
    (
        r.pareto.iter().map(|(_, o)| (o.0.to_bits(), o.1.to_bits())).collect(),
        r.pareto_islands.clone(),
        r.total_evaluations,
        r.cache_hits,
        r.migrations,
    )
}

fn assert_bit_identical(a: &SearchResult, b: &SearchResult, label: &str) {
    assert_eq!(fingerprint(a), fingerprint(b), "{label}: result fingerprints diverged");
    assert_eq!(a.history.len(), b.history.len(), "{label}: history length");
    for (x, y) in a.history.iter().zip(b.history.iter()) {
        assert_eq!(
            (x.gen, x.island, x.evaluated, x.valid, x.front_size),
            (y.gen, y.island, y.evaluated, y.valid, y.front_size),
            "{label}: history row diverged"
        );
        assert_eq!(x.best_time.to_bits(), y.best_time.to_bits(), "{label}: best_time bits");
        assert_eq!(x.best_error.to_bits(), y.best_error.to_bits(), "{label}: best_error bits");
    }
    assert_eq!(a.islands.len(), b.islands.len(), "{label}: island count");
    for (x, y) in a.islands.iter().zip(b.islands.iter()) {
        assert_eq!(
            (x.island, x.evaluations, x.cache_hits, x.front_size, x.migrants_sent, x.migrants_received),
            (y.island, y.evaluations, y.cache_hits, y.front_size, y.migrants_sent, y.migrants_received),
            "{label}: per-island stats diverged"
        );
    }
}

#[test]
fn threaded_runs_are_bit_identical_to_sequential() {
    let (g, eval) = toy();
    for k in [1usize, 2, 4] {
        let cfg = SearchConfig {
            pop_size: 6,
            generations: 4,
            elites: 3,
            workers: 1,
            seed: 19,
            islands: k,
            migration_interval: 2,
            migrants: 1,
            island_threads: 1,
            ..Default::default()
        };
        let sequential = run_with_checkpoint(&g, &eval, &cfg, None);
        for threads in [2usize, 4, 8] {
            let threaded = run_with_checkpoint(
                &g,
                &eval,
                &SearchConfig { island_threads: threads, ..cfg.clone() },
                None,
            );
            assert_bit_identical(
                &sequential,
                &threaded,
                &format!("islands={k} island_threads={threads}"),
            );
        }
    }
}

#[test]
fn threaded_kill_resume_is_bit_identical_and_leaves_no_temp_files() {
    let (g, eval) = toy();
    let dir = std::env::temp_dir().join(format!("gevo_thr_resume_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ck = dir.join("ck.json");
    let cfg = SearchConfig {
        pop_size: 6,
        generations: 5,
        elites: 3,
        workers: 1,
        seed: 29,
        islands: 3,
        migration_interval: 2,
        migrants: 1,
        island_threads: 3,
        checkpoint_every: 2,
        ..Default::default()
    };
    let uninterrupted = run_with_checkpoint(&g, &eval, &cfg, None);

    // "kill" after three generations (mid-segment relative to the full
    // 5-generation target), then resume from the async-written checkpoint
    let partial_cfg = SearchConfig { generations: 3, ..cfg.clone() };
    let partial = run_with_checkpoint(&g, &eval, &partial_cfg, Some(&ck));
    assert!(ck.exists(), "the writer thread must have installed the checkpoint");
    assert!(partial.history.len() < uninterrupted.history.len());
    let resumed = run_with_checkpoint(&g, &eval, &cfg, Some(&ck));
    assert_bit_identical(&uninterrupted, &resumed, "threaded resume");

    // durable-write hygiene: no abandoned temp files next to the target
    let leftovers: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.contains(".tmp."))
        .collect();
    assert!(leftovers.is_empty(), "temp files must not survive: {leftovers:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// An evaluator that panics on every variant cheaper than the baseline —
/// deterministic, so the surviving trajectory is too.
fn panicky() -> (Graph, impl Evaluator) {
    let (g, inner) = toy();
    let baseline_flops = g.total_flops();
    let eval = move |vg: &Graph| -> Option<Objectives> {
        if vg.total_flops() < baseline_flops {
            panic!("injected worker panic (variant cheaper than baseline)");
        }
        inner.evaluate(vg)
    };
    (g, eval)
}

#[test]
fn search_survives_a_panicking_evaluation_worker() {
    // One panicking evaluation must not take down the batch, the island
    // thread, the checkpoint writer, or the run: the panicking candidate
    // scores None (exactly like an invalid variant) and the search
    // completes across parallel workers and parallel islands.
    let (g, eval) = panicky();
    let dir = std::env::temp_dir().join(format!("gevo_panic_ck_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ck = dir.join("ck.json");
    let cfg = SearchConfig {
        pop_size: 6,
        generations: 3,
        elites: 3,
        workers: 2,
        seed: 23,
        islands: 2,
        migration_interval: 2,
        migrants: 1,
        island_threads: 2,
        ..Default::default()
    };
    let r = run_with_checkpoint(&g, &eval, &cfg, Some(&ck));
    assert!(ck.exists(), "the checkpoint writer must survive evaluator panics");
    assert_eq!(r.history.len(), 3 * 2, "every generation must complete on every island");
    assert!(
        r.pareto.iter().all(|(_, o)| o.0.to_bits() >= 1.0f64.to_bits()),
        "panicked (cheaper-than-baseline) variants must never reach the front"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn panicking_evaluator_trajectory_is_deterministic() {
    // With one worker the panic set is deterministic, so two runs (and a
    // threaded run) must agree bit-for-bit even while panics are caught.
    let (g, eval) = panicky();
    let cfg = SearchConfig {
        pop_size: 6,
        generations: 3,
        elites: 3,
        workers: 1,
        seed: 23,
        islands: 2,
        migration_interval: 2,
        migrants: 1,
        island_threads: 1,
        ..Default::default()
    };
    let a = run_with_checkpoint(&g, &eval, &cfg, None);
    let b = run_with_checkpoint(&g, &eval, &cfg, None);
    assert_bit_identical(&a, &b, "panicky repeat");
    let t = run_with_checkpoint(
        &g,
        &eval,
        &SearchConfig { island_threads: 2, ..cfg.clone() },
        None,
    );
    assert_bit_identical(&a, &t, "panicky threaded");
}
