//! Differential testing: the compiled execution engine ([`gevo_ml::exec`])
//! must be **bit-identical** to the tree-walking interpreter
//! ([`gevo_ml::interp`]) — same output bits on success, same
//! [`EvalError`] class on failure — across hundreds of seeded random
//! mutation chains over both paper workload graphs. This is the contract
//! that lets the fitness loop run compiled while `interp::eval` stays the
//! executable reference semantics.

use gevo_ml::evo::mutate::valid_random_edit;
use gevo_ml::exec::{Program, Scratch};
use gevo_ml::interp::{eval, EvalError};
use gevo_ml::ir::Graph;
use gevo_ml::models::{mobilenet, twofc};
use gevo_ml::tensor::Tensor;
use gevo_ml::util::prop::run_prop;
use gevo_ml::util::rng::Rng;

fn twofc_base() -> Graph {
    let spec = twofc::TwoFcSpec { batch: 4, input: 16, hidden: 8, classes: 4, lr: 0.1 };
    twofc::train_step_graph(&spec)
}

fn mobilenet_base() -> Graph {
    let spec =
        mobilenet::MobileNetSpec { batch: 2, side: 8, classes: 4, width: 4, blocks: 2 };
    let w = mobilenet::random_weights(&spec, 3);
    mobilenet::predict_graph(&spec, &w)
}

/// Apply a random chain of 1..=4 valid edits to `base`.
fn mutate_chain(base: &Graph, rng: &mut Rng) -> Graph {
    let mut g = base.clone();
    for _ in 0..rng.range(1, 5) {
        if let Some((_, ng)) = valid_random_edit(&g, rng, 25) {
            g = ng;
        }
    }
    g
}

fn random_inputs(g: &Graph, rng: &mut Rng) -> Vec<Tensor> {
    g.param_types()
        .iter()
        .map(|t| Tensor::rand_uniform(&t.dims, 0.0, 1.0, rng))
        .collect()
}

/// Outputs must agree bit-for-bit, including NaN payloads (mutants are
/// often numerically broken; both engines must be broken identically).
fn assert_bit_identical(want: &[Tensor], got: &[Tensor]) -> Result<(), String> {
    if want.len() != got.len() {
        return Err(format!("output count {} vs {}", want.len(), got.len()));
    }
    for (i, (w, g)) in want.iter().zip(got.iter()).enumerate() {
        if w.dims() != g.dims() {
            return Err(format!("output {i}: dims {:?} vs {:?}", w.dims(), g.dims()));
        }
        for (j, (a, b)) in w.data().iter().zip(g.data().iter()).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!(
                    "output {i}[{j}]: interp {a} ({:#010x}) vs exec {b} ({:#010x})",
                    a.to_bits(),
                    b.to_bits()
                ));
            }
        }
    }
    Ok(())
}

fn differential_case(base: &Graph, rng: &mut Rng) -> Result<(), String> {
    let g = mutate_chain(base, rng);
    let prog = Program::compile(&g).map_err(|e| format!("compile failed: {e}"))?;
    let inputs = random_inputs(&g, rng);
    let want = eval(&g, &inputs).map_err(|e| format!("interp failed: {e}"))?;
    let mut scratch = Scratch::new();
    let got = prog
        .run_with(&inputs, &mut scratch)
        .map_err(|e| format!("exec failed: {e}"))?;
    assert_bit_identical(&want, &got)?;
    // Re-run with warm scratch (recycled buffers must not leak stale data).
    let again = prog
        .run_with(&inputs, &mut scratch)
        .map_err(|e| format!("warm exec failed: {e}"))?;
    assert_bit_identical(&want, &again).map_err(|e| format!("warm run: {e}"))
}

#[test]
fn twofc_mutation_chains_bit_identical() {
    let base = twofc_base();
    run_prop(150, 0xD1FF, |rng| differential_case(&base, rng));
}

#[test]
fn mobilenet_mutation_chains_bit_identical() {
    let base = mobilenet_base();
    run_prop(100, 0xD2FF, |rng| differential_case(&base, rng));
}

/// Failing variants must fail with the same `EvalError` class in both
/// engines: wrong argument count and wrong argument shapes.
#[test]
fn error_classes_agree_on_both_workloads() {
    for base in [twofc_base(), mobilenet_base()] {
        run_prop(40, 0xE44, |rng| {
            let g = mutate_chain(&base, rng);
            let prog = Program::compile(&g).map_err(|e| format!("compile: {e}"))?;
            let mut inputs = random_inputs(&g, rng);

            // wrong count: drop one input
            let dropped = inputs.pop().expect("graphs have parameters");
            let ei = eval(&g, &inputs).expect_err("interp must reject short inputs");
            let ec = prog.run(&inputs).expect_err("exec must reject short inputs");
            if std::mem::discriminant(&ei) != std::mem::discriminant(&ec) {
                return Err(format!("count error class: interp {ei:?} vs exec {ec:?}"));
            }
            if !matches!(ei, EvalError::ArgCount { .. }) {
                return Err(format!("expected ArgCount, interp said {ei:?}"));
            }
            inputs.push(dropped);

            // wrong shape: corrupt one random input's dims
            let k = rng.below(inputs.len());
            let mut dims = inputs[k].dims().to_vec();
            if dims.is_empty() {
                dims.push(2); // scalar param -> rank-1
            } else {
                dims[0] += 1;
            }
            inputs[k] = Tensor::zeros(&dims);
            let ei = eval(&g, &inputs).expect_err("interp must reject bad shape");
            let ec = prog.run(&inputs).expect_err("exec must reject bad shape");
            if ei != ec {
                return Err(format!("shape error mismatch: interp {ei:?} vs exec {ec:?}"));
            }
            Ok(())
        });
    }
}

/// Tentpole contract: stacked-lane execution ([`Program::run_lanes`]) is
/// bit-identical to the scalar path, lane by lane, at every optimizer
/// level and on both workload graph families — batching is scheduling,
/// not semantics.
#[test]
fn batched_lanes_bit_identical_to_scalar_at_every_opt_level() {
    use gevo_ml::exec::cache::ProgramCache;
    use gevo_ml::exec::BatchScratch;
    use gevo_ml::opt::OptLevel;

    for level in [OptLevel::O0, OptLevel::O2, OptLevel::O3] {
        for base in [twofc_base(), mobilenet_base()] {
            let cache = ProgramCache::with_opt(level);
            let mut scratch = BatchScratch::new();
            run_prop(25, 0xBA7C, |rng| {
                let g = mutate_chain(&base, rng);
                let prog =
                    cache.get_or_compile(&g).map_err(|e| format!("compile: {e}"))?;
                let lane_inputs: Vec<Vec<Tensor>> =
                    (0..4).map(|_| random_inputs(&g, rng)).collect();
                let lane_refs: Vec<Vec<&Tensor>> =
                    lane_inputs.iter().map(|l| l.iter().collect()).collect();
                let lanes: Vec<&[&Tensor]> =
                    lane_refs.iter().map(|l| l.as_slice()).collect();
                let got = prog.run_lanes(&lanes, &mut scratch);
                if got.len() != lanes.len() {
                    return Err(format!("{} lanes in, {} results out", lanes.len(), got.len()));
                }
                for (v, inputs) in lane_inputs.iter().enumerate() {
                    let want = prog
                        .run(inputs)
                        .map_err(|e| format!("scalar lane {v} failed: {e}"))?;
                    let gotv = got[v]
                        .as_ref()
                        .map_err(|e| format!("batched lane {v} failed: {e:?}"))?;
                    assert_bit_identical(&want, gotv)
                        .map_err(|e| format!("level {level} lane {v}: {e}"))?;
                }
                Ok(())
            });
        }
    }
}

/// EvalError parity when one lane of a stacked batch is broken: the bad
/// lane must fail with exactly the scalar path's error, and the healthy
/// lanes must stay bit-identical — one sick genome cannot poison its
/// cohort.
#[test]
fn bad_lane_fails_with_scalar_error_and_leaves_cohort_intact() {
    use gevo_ml::exec::BatchScratch;

    for base in [twofc_base(), mobilenet_base()] {
        run_prop(20, 0xBAD1, |rng| {
            let g = mutate_chain(&base, rng);
            let prog = Program::compile(&g).map_err(|e| format!("compile: {e}"))?;
            let good_a = random_inputs(&g, rng);
            let good_b = random_inputs(&g, rng);
            let mut bad = random_inputs(&g, rng);
            let k = rng.below(bad.len());
            let mut dims = bad[k].dims().to_vec();
            if dims.is_empty() {
                dims.push(2);
            } else {
                dims[0] += 1;
            }
            bad[k] = Tensor::zeros(&dims);

            let lane_sets: [&Vec<Tensor>; 3] = [&good_a, &bad, &good_b];
            let lane_refs: Vec<Vec<&Tensor>> =
                lane_sets.iter().map(|l| l.iter().collect()).collect();
            let lanes: Vec<&[&Tensor]> = lane_refs.iter().map(|l| l.as_slice()).collect();
            let mut scratch = BatchScratch::new();
            let got = prog.run_lanes(&lanes, &mut scratch);

            let want_err = prog.run(&bad).expect_err("scalar must reject the bad shape");
            match &got[1] {
                Err(e) if *e == want_err => {}
                other => {
                    return Err(format!("bad lane: want Err({want_err:?}), got {other:?}"))
                }
            }
            for (v, inputs) in [(0usize, &good_a), (2usize, &good_b)] {
                let want =
                    prog.run(inputs).map_err(|e| format!("scalar lane {v}: {e}"))?;
                let gotv = got[v]
                    .as_ref()
                    .map_err(|e| format!("healthy lane {v} failed in batch: {e:?}"))?;
                assert_bit_identical(&want, gotv).map_err(|e| format!("lane {v}: {e}"))?;
            }
            Ok(())
        });
    }
}

/// End-to-end tentpole pin: a search with cohort batching on (`batch`
/// 32) reproduces the genome-at-a-time path (`batch` 0) bit for bit —
/// Pareto front, per-generation history, evaluation and cache counters —
/// at O0, O2 and O3. The only thing allowed to differ is the
/// [`BatchStats`] observables themselves.
#[test]
fn batched_search_front_history_and_counters_match_scalar_path() {
    use gevo_ml::data::digits;
    use gevo_ml::evo::search::{self, SearchConfig};
    use gevo_ml::fitness::training::TrainingWorkload;
    use gevo_ml::fitness::RuntimeMetric;
    use gevo_ml::opt::OptLevel;

    let spec = twofc::TwoFcSpec { batch: 8, input: 16, hidden: 8, classes: 4, lr: 0.1 };
    let base = twofc::train_step_graph(&spec);
    for level in [OptLevel::O0, OptLevel::O2, OptLevel::O3] {
        let run_once = |batch: usize| {
            let data = digits::generate(96, spec.side(), 7);
            let (fit, test) = data.split(64);
            let wl = TrainingWorkload::new_with_opt(
                spec,
                &base,
                fit,
                test,
                1,
                1,
                RuntimeMetric::Flops,
                level,
            );
            let cfg = SearchConfig {
                pop_size: 8,
                generations: 3,
                elites: 4,
                workers: 3,
                seed: 11,
                batch,
                opt_level: level,
                verbose: false,
                ..Default::default()
            };
            let r = search::run(&base, &wl, &cfg);
            (
                r.pareto
                    .iter()
                    .map(|(_, o)| (o.0.to_bits(), o.1.to_bits()))
                    .collect::<Vec<_>>(),
                r.pareto_islands.clone(),
                format!("{:?}", r.history),
                r.total_evaluations,
                r.cache_hits,
                r.program_batch,
            )
        };
        let scalar = run_once(0);
        let batched = run_once(32);
        assert!(!batched.0.is_empty(), "search must produce a front at {level}");
        assert_eq!(scalar.0, batched.0, "front bits must match at {level}");
        assert_eq!(scalar.1, batched.1, "front islands must match at {level}");
        assert_eq!(scalar.2, batched.2, "history must match at {level}");
        assert_eq!(scalar.3, batched.3, "total_evaluations must match at {level}");
        assert_eq!(scalar.4, batched.4, "cache_hits must match at {level}");
        // The batch observables are the one legitimate difference:
        // `--batch 0` never forms a cohort.
        let b = scalar.5.expect("workload reports batch stats");
        assert_eq!(b.cohorts, 0, "scalar path must not form cohorts");
        assert_eq!(b.batched_evals, 0);
    }
}

/// Same end-to-end pin for the prediction workload, whose cohorts
/// actually stack the fitness mini-batches into lanes of one
/// [`Program::run_lanes`] call.
#[test]
fn batched_prediction_search_matches_scalar_path() {
    use gevo_ml::data::patterns;
    use gevo_ml::evo::search::{self, SearchConfig};
    use gevo_ml::fitness::prediction::PredictionWorkload;
    use gevo_ml::fitness::RuntimeMetric;

    let spec =
        mobilenet::MobileNetSpec { batch: 2, side: 8, classes: 4, width: 4, blocks: 2 };
    let w = mobilenet::random_weights(&spec, 3);
    let base = mobilenet::predict_graph(&spec, &w);
    let run_once = |batch: usize| {
        let data = patterns::generate(48, spec.side, 7);
        let (fit, test) = data.split(32);
        let wl =
            PredictionWorkload::new(&base, spec.batch, &fit, &test, 8, RuntimeMetric::Flops);
        let cfg = SearchConfig {
            pop_size: 6,
            generations: 2,
            elites: 3,
            workers: 2,
            seed: 5,
            batch,
            verbose: false,
            ..Default::default()
        };
        let r = search::run(&base, &wl, &cfg);
        (
            r.pareto
                .iter()
                .map(|(_, o)| (o.0.to_bits(), o.1.to_bits()))
                .collect::<Vec<_>>(),
            r.total_evaluations,
            r.cache_hits,
        )
    };
    let scalar = run_once(0);
    let batched = run_once(32);
    assert!(!batched.0.is_empty());
    assert_eq!(scalar, batched, "prediction search must be batch-invariant");
}

/// The deepest fingerprint: the final checkpoint — population genomes,
/// archives, per-island RNG states, operator weights — must be
/// byte-identical whether evaluation batched or not. `batch` is
/// scheduling only and is excluded from the checkpoint's config echo.
#[test]
fn checkpoint_bytes_identical_regardless_of_batch_width() {
    use gevo_ml::data::digits;
    use gevo_ml::evo::island;
    use gevo_ml::evo::search::SearchConfig;
    use gevo_ml::fitness::training::TrainingWorkload;
    use gevo_ml::fitness::RuntimeMetric;

    let spec = twofc::TwoFcSpec { batch: 8, input: 16, hidden: 8, classes: 4, lr: 0.1 };
    let base = twofc::train_step_graph(&spec);
    let dir = std::env::temp_dir().join(format!("gevo_batch_ck_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let run_once = |batch: usize, name: &str| -> Vec<u8> {
        let ck = dir.join(name);
        let data = digits::generate(96, spec.side(), 7);
        let (fit, test) = data.split(64);
        let wl = TrainingWorkload::new(spec, &base, fit, test, 1, 1, RuntimeMetric::Flops);
        let cfg = SearchConfig {
            pop_size: 6,
            generations: 3,
            elites: 3,
            workers: 2,
            islands: 2,
            migration_interval: 2,
            seed: 17,
            batch,
            verbose: false,
            ..Default::default()
        };
        island::run_with_checkpoint(&base, &wl, &cfg, Some(&ck));
        std::fs::read(&ck).unwrap()
    };
    let a = run_once(0, "scalar.json");
    let b = run_once(32, "batched.json");
    assert_eq!(a, b, "checkpoints diverged: batching leaked into search state");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite regression: two `search::run` invocations with the same seed
/// and `RuntimeMetric::Flops` must produce identical Pareto fronts when
/// every fitness evaluation goes through the compiled engine.
#[test]
fn search_deterministic_through_compiled_engine() {
    use gevo_ml::data::digits;
    use gevo_ml::evo::search::{self, SearchConfig};
    use gevo_ml::fitness::training::TrainingWorkload;
    use gevo_ml::fitness::RuntimeMetric;

    let spec = twofc::TwoFcSpec { batch: 8, input: 16, hidden: 8, classes: 4, lr: 0.1 };
    let base = twofc::train_step_graph(&spec);
    let cfg = SearchConfig {
        pop_size: 8,
        generations: 3,
        elites: 4,
        workers: 3,
        seed: 11,
        verbose: false,
        // the workload below is built with `new` (an O0 program cache);
        // the search cross-checks the two levels agree
        opt_level: gevo_ml::opt::OptLevel::O0,
        ..Default::default()
    };
    let run_once = || {
        let data = digits::generate(96, spec.side(), 7);
        let (fit, test) = data.split(64);
        let wl = TrainingWorkload::new(spec, &base, fit, test, 1, 1, RuntimeMetric::Flops);
        let res = search::run(&base, &wl, &cfg);
        assert!(res.program_cache.is_some(), "workload must report its program cache");
        res.pareto.iter().map(|(_, o)| *o).collect::<Vec<_>>()
    };
    let a = run_once();
    let b = run_once();
    assert!(!a.is_empty());
    assert_eq!(a, b, "same seed + Flops metric must reproduce the same front");
}
