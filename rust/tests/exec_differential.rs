//! Differential testing: the compiled execution engine ([`gevo_ml::exec`])
//! must be **bit-identical** to the tree-walking interpreter
//! ([`gevo_ml::interp`]) — same output bits on success, same
//! [`EvalError`] class on failure — across hundreds of seeded random
//! mutation chains over both paper workload graphs. This is the contract
//! that lets the fitness loop run compiled while `interp::eval` stays the
//! executable reference semantics.

use gevo_ml::evo::mutate::valid_random_edit;
use gevo_ml::exec::{Program, Scratch};
use gevo_ml::interp::{eval, EvalError};
use gevo_ml::ir::Graph;
use gevo_ml::models::{mobilenet, twofc};
use gevo_ml::tensor::Tensor;
use gevo_ml::util::prop::run_prop;
use gevo_ml::util::rng::Rng;

fn twofc_base() -> Graph {
    let spec = twofc::TwoFcSpec { batch: 4, input: 16, hidden: 8, classes: 4, lr: 0.1 };
    twofc::train_step_graph(&spec)
}

fn mobilenet_base() -> Graph {
    let spec =
        mobilenet::MobileNetSpec { batch: 2, side: 8, classes: 4, width: 4, blocks: 2 };
    let w = mobilenet::random_weights(&spec, 3);
    mobilenet::predict_graph(&spec, &w)
}

/// Apply a random chain of 1..=4 valid edits to `base`.
fn mutate_chain(base: &Graph, rng: &mut Rng) -> Graph {
    let mut g = base.clone();
    for _ in 0..rng.range(1, 5) {
        if let Some((_, ng)) = valid_random_edit(&g, rng, 25) {
            g = ng;
        }
    }
    g
}

fn random_inputs(g: &Graph, rng: &mut Rng) -> Vec<Tensor> {
    g.param_types()
        .iter()
        .map(|t| Tensor::rand_uniform(&t.dims, 0.0, 1.0, rng))
        .collect()
}

/// Outputs must agree bit-for-bit, including NaN payloads (mutants are
/// often numerically broken; both engines must be broken identically).
fn assert_bit_identical(want: &[Tensor], got: &[Tensor]) -> Result<(), String> {
    if want.len() != got.len() {
        return Err(format!("output count {} vs {}", want.len(), got.len()));
    }
    for (i, (w, g)) in want.iter().zip(got.iter()).enumerate() {
        if w.dims() != g.dims() {
            return Err(format!("output {i}: dims {:?} vs {:?}", w.dims(), g.dims()));
        }
        for (j, (a, b)) in w.data().iter().zip(g.data().iter()).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!(
                    "output {i}[{j}]: interp {a} ({:#010x}) vs exec {b} ({:#010x})",
                    a.to_bits(),
                    b.to_bits()
                ));
            }
        }
    }
    Ok(())
}

fn differential_case(base: &Graph, rng: &mut Rng) -> Result<(), String> {
    let g = mutate_chain(base, rng);
    let prog = Program::compile(&g).map_err(|e| format!("compile failed: {e}"))?;
    let inputs = random_inputs(&g, rng);
    let want = eval(&g, &inputs).map_err(|e| format!("interp failed: {e}"))?;
    let mut scratch = Scratch::new();
    let got = prog
        .run_with(&inputs, &mut scratch)
        .map_err(|e| format!("exec failed: {e}"))?;
    assert_bit_identical(&want, &got)?;
    // Re-run with warm scratch (recycled buffers must not leak stale data).
    let again = prog
        .run_with(&inputs, &mut scratch)
        .map_err(|e| format!("warm exec failed: {e}"))?;
    assert_bit_identical(&want, &again).map_err(|e| format!("warm run: {e}"))
}

#[test]
fn twofc_mutation_chains_bit_identical() {
    let base = twofc_base();
    run_prop(150, 0xD1FF, |rng| differential_case(&base, rng));
}

#[test]
fn mobilenet_mutation_chains_bit_identical() {
    let base = mobilenet_base();
    run_prop(100, 0xD2FF, |rng| differential_case(&base, rng));
}

/// Failing variants must fail with the same `EvalError` class in both
/// engines: wrong argument count and wrong argument shapes.
#[test]
fn error_classes_agree_on_both_workloads() {
    for base in [twofc_base(), mobilenet_base()] {
        run_prop(40, 0xE44, |rng| {
            let g = mutate_chain(&base, rng);
            let prog = Program::compile(&g).map_err(|e| format!("compile: {e}"))?;
            let mut inputs = random_inputs(&g, rng);

            // wrong count: drop one input
            let dropped = inputs.pop().expect("graphs have parameters");
            let ei = eval(&g, &inputs).expect_err("interp must reject short inputs");
            let ec = prog.run(&inputs).expect_err("exec must reject short inputs");
            if std::mem::discriminant(&ei) != std::mem::discriminant(&ec) {
                return Err(format!("count error class: interp {ei:?} vs exec {ec:?}"));
            }
            if !matches!(ei, EvalError::ArgCount { .. }) {
                return Err(format!("expected ArgCount, interp said {ei:?}"));
            }
            inputs.push(dropped);

            // wrong shape: corrupt one random input's dims
            let k = rng.below(inputs.len());
            let mut dims = inputs[k].dims().to_vec();
            if dims.is_empty() {
                dims.push(2); // scalar param -> rank-1
            } else {
                dims[0] += 1;
            }
            inputs[k] = Tensor::zeros(&dims);
            let ei = eval(&g, &inputs).expect_err("interp must reject bad shape");
            let ec = prog.run(&inputs).expect_err("exec must reject bad shape");
            if ei != ec {
                return Err(format!("shape error mismatch: interp {ei:?} vs exec {ec:?}"));
            }
            Ok(())
        });
    }
}

/// Satellite regression: two `search::run` invocations with the same seed
/// and `RuntimeMetric::Flops` must produce identical Pareto fronts when
/// every fitness evaluation goes through the compiled engine.
#[test]
fn search_deterministic_through_compiled_engine() {
    use gevo_ml::data::digits;
    use gevo_ml::evo::search::{self, SearchConfig};
    use gevo_ml::fitness::training::TrainingWorkload;
    use gevo_ml::fitness::RuntimeMetric;

    let spec = twofc::TwoFcSpec { batch: 8, input: 16, hidden: 8, classes: 4, lr: 0.1 };
    let base = twofc::train_step_graph(&spec);
    let cfg = SearchConfig {
        pop_size: 8,
        generations: 3,
        elites: 4,
        workers: 3,
        seed: 11,
        verbose: false,
        // the workload below is built with `new` (an O0 program cache);
        // the search cross-checks the two levels agree
        opt_level: gevo_ml::opt::OptLevel::O0,
        ..Default::default()
    };
    let run_once = || {
        let data = digits::generate(96, spec.side(), 7);
        let (fit, test) = data.split(64);
        let wl = TrainingWorkload::new(spec, &base, fit, test, 1, 1, RuntimeMetric::Flops);
        let res = search::run(&base, &wl, &cfg);
        assert!(res.program_cache.is_some(), "workload must report its program cache");
        res.pareto.iter().map(|(_, o)| *o).collect::<Vec<_>>()
    };
    let a = run_once();
    let b = run_once();
    assert!(!a.is_empty());
    assert_eq!(a, b, "same seed + Flops metric must reproduce the same front");
}
