//! Differential testing of the optimizer pipeline ([`gevo_ml::opt`]):
//! for hundreds of seeded random mutation chains over both paper workload
//! graphs, the **optimized** graph — compiled and executed — must be
//! **bit-identical** to the unoptimized graph interpreted by
//! [`gevo_ml::interp`], at every opt level, and failing inputs must fail
//! with the same [`EvalError`] class. This is the contract that lets the
//! ProgramCache canonicalize graphs on the fitness hot path while the
//! search's results stay byte-for-byte reproducible (mirrors
//! `tests/exec_differential.rs`, which pins the compiled engine itself).

use gevo_ml::evo::mutate::valid_random_edit;
use gevo_ml::exec::cache::ProgramCache;
use gevo_ml::exec::{Program, Scratch};
use gevo_ml::interp::{eval, EvalError};
use gevo_ml::ir::Graph;
use gevo_ml::models::{mobilenet, twofc};
use gevo_ml::opt::{optimize, OptLevel};
use gevo_ml::tensor::Tensor;
use gevo_ml::util::prop::run_prop;
use gevo_ml::util::rng::Rng;

fn twofc_base() -> Graph {
    let spec = twofc::TwoFcSpec { batch: 4, input: 16, hidden: 8, classes: 4, lr: 0.1 };
    twofc::train_step_graph(&spec)
}

fn mobilenet_base() -> Graph {
    let spec =
        mobilenet::MobileNetSpec { batch: 2, side: 8, classes: 4, width: 4, blocks: 2 };
    let w = mobilenet::random_weights(&spec, 3);
    mobilenet::predict_graph(&spec, &w)
}

/// Apply a random chain of 1..=4 valid edits to `base`.
fn mutate_chain(base: &Graph, rng: &mut Rng) -> Graph {
    let mut g = base.clone();
    for _ in 0..rng.range(1, 5) {
        if let Some((_, ng)) = valid_random_edit(&g, rng, 25) {
            g = ng;
        }
    }
    g
}

fn random_inputs(g: &Graph, rng: &mut Rng) -> Vec<Tensor> {
    g.param_types()
        .iter()
        .map(|t| Tensor::rand_uniform(&t.dims, 0.0, 1.0, rng))
        .collect()
}

/// Outputs must agree bit-for-bit, NaN payloads included (mutants are
/// often numerically broken; optimized and raw graphs must be broken
/// identically).
fn assert_bit_identical(want: &[Tensor], got: &[Tensor]) -> Result<(), String> {
    if want.len() != got.len() {
        return Err(format!("output count {} vs {}", want.len(), got.len()));
    }
    for (i, (w, g)) in want.iter().zip(got.iter()).enumerate() {
        if w.dims() != g.dims() {
            return Err(format!("output {i}: dims {:?} vs {:?}", w.dims(), g.dims()));
        }
        for (j, (a, b)) in w.data().iter().zip(g.data().iter()).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!(
                    "output {i}[{j}]: raw {a} ({:#010x}) vs optimized {b} ({:#010x})",
                    a.to_bits(),
                    b.to_bits()
                ));
            }
        }
    }
    Ok(())
}

/// Compile the way the program cache does at this level: `O3` lowers
/// through the kernel-fusion path, everything below stays unfused.
fn compile_at(g: &Graph, level: OptLevel) -> Result<Program, gevo_ml::ir::IrError> {
    if level >= OptLevel::O3 {
        Program::compile_fused(g)
    } else {
        Program::compile(g)
    }
}

fn differential_case(base: &Graph, rng: &mut Rng) -> Result<(), String> {
    let g = mutate_chain(base, rng);
    let inputs = random_inputs(&g, rng);
    let want = eval(&g, &inputs).map_err(|e| format!("interp failed: {e}"))?;
    for level in [OptLevel::O1, OptLevel::O2, OptLevel::O3] {
        let (og, _) = optimize(&g, level);
        gevo_ml::ir::verify::verify(&og)
            .map_err(|e| format!("level {level}: optimized graph invalid: {e}"))?;
        if og.param_types() != g.param_types() || og.output_types() != g.output_types() {
            return Err(format!("level {level}: optimization changed the signature"));
        }
        // interpreted optimized graph
        let got = eval(&og, &inputs).map_err(|e| format!("level {level}: interp: {e}"))?;
        assert_bit_identical(&want, &got).map_err(|e| format!("level {level} interp: {e}"))?;
        // compiled optimized graph (fused at O3), cold and warm scratch
        let prog =
            compile_at(&og, level).map_err(|e| format!("level {level}: compile: {e}"))?;
        let mut scratch = Scratch::new();
        let got = prog
            .run_with(&inputs, &mut scratch)
            .map_err(|e| format!("level {level}: exec: {e}"))?;
        assert_bit_identical(&want, &got).map_err(|e| format!("level {level} exec: {e}"))?;
        let again = prog
            .run_with(&inputs, &mut scratch)
            .map_err(|e| format!("level {level}: warm exec: {e}"))?;
        assert_bit_identical(&want, &again)
            .map_err(|e| format!("level {level} warm exec: {e}"))?;
    }
    Ok(())
}

#[test]
fn twofc_mutation_chains_bit_identical_at_all_levels() {
    let base = twofc_base();
    run_prop(120, 0x09717, |rng| differential_case(&base, rng));
}

#[test]
fn mobilenet_mutation_chains_bit_identical_at_all_levels() {
    let base = mobilenet_base();
    run_prop(80, 0x09718, |rng| differential_case(&base, rng));
}

/// `--opt-level 0` must reproduce current behavior bit-identically: the
/// graph, its canonical hash, and the compiled program's results are
/// exactly those of the unoptimized path.
#[test]
fn opt_level_zero_is_the_identity() {
    let base = twofc_base();
    run_prop(40, 0x09719, |rng| {
        let g = mutate_chain(&base, rng);
        let (og, stats) = optimize(&g, OptLevel::O0);
        if stats.rewrites != 0 {
            return Err("O0 applied rewrites".into());
        }
        if gevo_ml::ir::printer::print(&g) != gevo_ml::ir::printer::print(&og) {
            return Err("O0 changed the printed graph".into());
        }
        if gevo_ml::ir::canon::graph_hash(&g) != gevo_ml::ir::canon::graph_hash(&og) {
            return Err("O0 changed the canonical hash".into());
        }
        Ok(())
    });
}

/// The pipeline is a deterministic fixed point: optimizing twice from the
/// same input prints identically, and re-optimizing an optimized graph
/// applies zero rewrites.
#[test]
fn optimizer_is_deterministic_and_idempotent() {
    for base in [twofc_base(), mobilenet_base()] {
        run_prop(40, 0x0971A, |rng| {
            let g = mutate_chain(&base, rng);
            for level in [OptLevel::O1, OptLevel::O2, OptLevel::O3] {
                let (a, sa) = optimize(&g, level);
                let (b, sb) = optimize(&g, level);
                if gevo_ml::ir::printer::print(&a) != gevo_ml::ir::printer::print(&b) {
                    return Err(format!("level {level}: nondeterministic output"));
                }
                if sa.rewrites != sb.rewrites || sa.rounds != sb.rounds {
                    return Err(format!("level {level}: nondeterministic stats"));
                }
                let (c, sc) = optimize(&a, level);
                if sc.rewrites != 0 {
                    return Err(format!(
                        "level {level}: fixed point not reached ({} more rewrites)",
                        sc.rewrites
                    ));
                }
                if gevo_ml::ir::printer::print(&a) != gevo_ml::ir::printer::print(&c) {
                    return Err(format!("level {level}: not idempotent"));
                }
            }
            Ok(())
        });
    }
}

/// Failing inputs fail identically: optimization never changes the entry
/// signature, so wrong argument counts and wrong shapes raise the same
/// `EvalError` class (and for shapes, the same error value) as the
/// unoptimized graph interpreted.
#[test]
fn error_classes_agree_after_optimization() {
    for base in [twofc_base(), mobilenet_base()] {
        run_prop(30, 0x0971B, |rng| {
            let g = mutate_chain(&base, rng);
            for level in [OptLevel::O2, OptLevel::O3] {
                let (og, _) = optimize(&g, level);
                let prog = compile_at(&og, level)
                    .map_err(|e| format!("level {level}: compile: {e}"))?;
                let mut inputs = random_inputs(&g, rng);

                // wrong count: drop one input
                let dropped = inputs.pop().expect("graphs have parameters");
                let ei = eval(&g, &inputs).expect_err("interp must reject short inputs");
                let ec =
                    prog.run(&inputs).expect_err("optimized exec must reject short inputs");
                if std::mem::discriminant(&ei) != std::mem::discriminant(&ec) {
                    return Err(format!(
                        "level {level} count error class: raw {ei:?} vs optimized {ec:?}"
                    ));
                }
                if !matches!(ei, EvalError::ArgCount { .. }) {
                    return Err(format!("expected ArgCount, interp said {ei:?}"));
                }
                inputs.push(dropped);

                // wrong shape: corrupt one random input's dims
                let k = rng.below(inputs.len());
                let mut dims = inputs[k].dims().to_vec();
                if dims.is_empty() {
                    dims.push(2);
                } else {
                    dims[0] += 1;
                }
                inputs[k] = Tensor::zeros(&dims);
                let ei = eval(&g, &inputs).expect_err("interp must reject bad shape");
                let ec = prog.run(&inputs).expect_err("optimized exec must reject bad shape");
                if ei != ec {
                    return Err(format!(
                        "level {level} shape error mismatch: raw {ei:?} vs optimized {ec:?}"
                    ));
                }
            }
            Ok(())
        });
    }
}

/// The O3 headline: on both seed workload graphs, fused lowering strictly
/// reduces the compiled step count and does not raise the arena
/// high-water mark (their regions are contiguous; this is not a universal
/// invariant — see `exec::FusionStats`) — while every fused program above
/// already proved bit-identical.
#[test]
fn o3_reduces_compiled_step_count_on_both_seed_workloads() {
    for g in [twofc_base(), mobilenet_base()] {
        let (og, _) = optimize(&g, OptLevel::O3);
        let unfused = Program::compile(&og).unwrap();
        let fused = Program::compile_fused(&og).unwrap();
        let stats = fused.fusion_stats().expect("fused compile records stats");
        assert!(stats.regions > 0, "'{}' must contain fusible regions", g.name);
        assert!(
            fused.num_slots() < unfused.num_slots(),
            "'{}': fusion must reduce step count ({} -> {})",
            g.name,
            unfused.num_slots(),
            fused.num_slots()
        );
        assert!(
            stats.peak_after <= stats.peak_before,
            "'{}': fused peak {} exceeds unfused {}",
            g.name,
            stats.peak_after,
            stats.peak_before
        );
    }
}

/// The tentpole cache claim: mutants that differ only by dead or
/// redundant edits share one ProgramCache entry once the cache
/// canonicalizes through the optimizer.
#[test]
fn optimizing_cache_collapses_redundant_mutants() {
    let base = twofc_base();
    // Twin A: the base plus an unused (dead) op.
    let mut dead_twin = base.clone();
    let anchor = dead_twin.insts()[0].id;
    dead_twin.push(gevo_ml::ir::OpKind::Exponential, &[anchor]).unwrap();
    // Twin B: the base plus a redundant recomputation of an existing op
    // wired nowhere (CSE + DCE food).
    let mut dup_twin = base.clone();
    let (kind, args) = {
        let inst = dup_twin
            .insts()
            .iter()
            .find(|i| !i.args.is_empty())
            .expect("graph has non-nullary ops")
            .clone();
        (inst.kind, inst.args)
    };
    let pos = dup_twin.len();
    dup_twin.insert_at(pos, kind, &args).unwrap();

    let o0 = ProgramCache::new();
    for g in [&base, &dead_twin, &dup_twin] {
        o0.get_or_compile(g).unwrap();
    }
    assert_eq!(o0.len(), 3, "at O0 the twins are distinct entries");

    for level in [OptLevel::O2, OptLevel::O3] {
        let c = ProgramCache::with_opt(level);
        let p0 = c.get_or_compile(&base).unwrap();
        let p1 = c.get_or_compile(&dead_twin).unwrap();
        let p2 = c.get_or_compile(&dup_twin).unwrap();
        assert_eq!(c.len(), 1, "at {level} all three canonicalize to one entry");
        assert!(std::sync::Arc::ptr_eq(&p0, &p1) && std::sync::Arc::ptr_eq(&p0, &p2));
        let (hits, misses) = c.stats();
        assert_eq!((hits, misses), (2, 1), "one lowering serves all three at {level}");
    }
}

/// Search determinism through the optimized cache: with the deterministic
/// `flops` metric, the same seed produces the same Pareto front at O0 and
/// O2 — optimization changes evaluation cost, never results.
#[test]
fn search_front_is_opt_level_invariant_under_flops_metric() {
    use gevo_ml::data::digits;
    use gevo_ml::evo::search::{self, SearchConfig};
    use gevo_ml::fitness::training::TrainingWorkload;
    use gevo_ml::fitness::RuntimeMetric;

    let spec = twofc::TwoFcSpec { batch: 8, input: 16, hidden: 8, classes: 4, lr: 0.1 };
    let base = twofc::train_step_graph(&spec);
    let run_at = |opt: OptLevel| {
        let cfg = SearchConfig {
            pop_size: 8,
            generations: 3,
            elites: 4,
            workers: 3,
            seed: 11,
            verbose: false,
            opt_level: opt,
            ..Default::default()
        };
        let data = digits::generate(96, spec.side(), 7);
        let (fit, test) = data.split(64);
        let wl = TrainingWorkload::new_with_opt(
            spec, &base, fit, test, 1, 1, RuntimeMetric::Flops, opt,
        );
        let res = search::run(&base, &wl, &cfg);
        res.pareto.iter().map(|(_, o)| *o).collect::<Vec<_>>()
    };
    let front0 = run_at(OptLevel::O0);
    let front2 = run_at(OptLevel::O2);
    let front3 = run_at(OptLevel::O3);
    assert!(!front0.is_empty());
    assert_eq!(front0, front2, "opt level must not change flops-metric search results");
    assert_eq!(front0, front3, "fused O3 execution must not change search results");
}
