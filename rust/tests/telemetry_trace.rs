//! Integration: the `--trace` telemetry stream over the public API.
//!
//! Pins the subsystem's core guarantee — tracing is *observational*:
//! a run with a trace attached produces bit-identical fronts, history,
//! lineage and checkpoint bytes to the same run without one, across
//! opt levels, island counts, island threads and batch widths. Also
//! covers kill/resume trace well-formedness, lineage surviving a
//! checkpoint roundtrip, and fail-fast on an unwritable trace path.

use gevo_ml::evo::island::{run_with_checkpoint, try_run_with_checkpoint};
use gevo_ml::evo::nsga2::Objectives;
use gevo_ml::evo::search::{Evaluator, SearchConfig, SearchResult};
use gevo_ml::ir::op::{OpKind, ReduceKind};
use gevo_ml::ir::types::TType;
use gevo_ml::ir::Graph;
use gevo_ml::opt::OptLevel;
use gevo_ml::util::json::Json;
use std::path::PathBuf;

/// The toy workload from the island tests: runtime = normalized FLOPs,
/// error = |output − baseline| on one input.
fn toy() -> (Graph, impl Evaluator) {
    let mut g = Graph::new("toy");
    let x = g.param(TType::of(&[4, 4]));
    let e1 = g.push(OpKind::Exponential, &[x]).unwrap();
    let t = g.push(OpKind::Tanh, &[e1]).unwrap();
    let a = g.push(OpKind::Add, &[t, x]).unwrap();
    let r = g
        .push(OpKind::Reduce { dims: vec![0, 1], kind: ReduceKind::Sum }, &[a])
        .unwrap();
    g.set_outputs(&[r]);
    let base_flops = g.total_flops() as f64;
    let input = gevo_ml::tensor::Tensor::iota(&[4, 4]);
    let baseline = gevo_ml::interp::eval(&g, &[input.clone()]).unwrap()[0].item() as f64;
    let eval = move |vg: &Graph| -> Option<Objectives> {
        let out = gevo_ml::interp::eval(vg, &[input.clone()]).ok()?;
        if out[0].has_non_finite() {
            return None;
        }
        let err = (out[0].item() as f64 - baseline).abs() / baseline.abs().max(1e-9);
        let time = vg.total_flops() as f64 / base_flops;
        Some((time, err))
    };
    (g, eval)
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("gevo_trace_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Everything a trace must not perturb, as exact bit patterns.
fn fingerprint(r: &SearchResult) -> Vec<(u64, u64)> {
    r.pareto.iter().map(|(_, o)| (o.0.to_bits(), o.1.to_bits())).collect()
}

fn assert_same_outcome(a: &SearchResult, b: &SearchResult, label: &str) {
    assert_eq!(fingerprint(a), fingerprint(b), "{label}: front bits diverged");
    assert_eq!(a.pareto_islands, b.pareto_islands, "{label}: front islands");
    assert_eq!(a.pareto_lineage, b.pareto_lineage, "{label}: front lineage");
    assert_eq!(a.total_evaluations, b.total_evaluations, "{label}: evaluations");
    assert_eq!(a.migrations, b.migrations, "{label}: migrations");
    assert_eq!(a.history.len(), b.history.len(), "{label}: history length");
    for (x, y) in a.history.iter().zip(b.history.iter()) {
        assert_eq!(
            (x.gen, x.island, x.evaluated, x.valid, x.front_size),
            (y.gen, y.island, y.evaluated, y.valid, y.front_size),
            "{label}: history row diverged"
        );
        assert_eq!(x.best_time.to_bits(), y.best_time.to_bits(), "{label}: best_time bits");
        assert_eq!(x.best_error.to_bits(), y.best_error.to_bits(), "{label}: best_error bits");
    }
}

/// Parse every line of a trace file; panics (with the offending line)
/// on anything that is not a one-object JSON record.
fn parse_trace(path: &std::path::Path) -> Vec<Json> {
    let text = std::fs::read_to_string(path).unwrap();
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).unwrap_or_else(|e| panic!("bad trace line {l:?}: {e:?}")))
        .collect()
}

fn kinds(events: &[Json]) -> Vec<String> {
    events
        .iter()
        .map(|e| e.get("kind").unwrap().as_str().unwrap().to_string())
        .collect()
}

#[test]
fn tracing_is_observational_across_schedules_and_opt_levels() {
    let (g, eval) = toy();
    let dir = tmp_dir("bitid");
    let mut case = 0usize;
    for (opt, islands, threads, batch) in [
        (OptLevel::parse("0").unwrap(), 1usize, 1usize, 0usize),
        (OptLevel::parse("2").unwrap(), 2, 1, 32),
        (OptLevel::parse("3").unwrap(), 3, 3, 4),
    ] {
        case += 1;
        let base = SearchConfig {
            pop_size: 6,
            generations: 4,
            elites: 3,
            workers: 1,
            seed: 19,
            islands,
            migration_interval: 2,
            migrants: 1,
            island_threads: threads,
            batch,
            opt_level: opt,
            checkpoint_every: 2,
            ..Default::default()
        };
        let label = format!("opt={opt} islands={islands} threads={threads} batch={batch}");
        let ck_off = dir.join(format!("off_{case}.json"));
        let ck_on = dir.join(format!("on_{case}.json"));
        let trace = dir.join(format!("trace_{case}.jsonl"));
        let off = run_with_checkpoint(&g, &eval, &base, Some(&ck_off));
        let on = run_with_checkpoint(
            &g,
            &eval,
            &SearchConfig { trace: Some(trace.clone()), ..base.clone() },
            Some(&ck_on),
        );
        assert_same_outcome(&off, &on, &label);
        // The checkpoint the writer installed must be byte-identical:
        // tracing may not leak into persisted state.
        let a = std::fs::read(&ck_off).unwrap();
        let b = std::fs::read(&ck_on).unwrap();
        assert_eq!(a, b, "{label}: checkpoint bytes diverged under tracing");
        // And the stream itself is well-formed with the lifecycle kinds.
        let ks = kinds(&parse_trace(&trace));
        assert_eq!(ks.first().map(|s| s.as_str()), Some("run_start"), "{label}");
        assert_eq!(ks.last().map(|s| s.as_str()), Some("run_end"), "{label}");
        for want in ["gen", "checkpoint", "front"] {
            assert!(ks.iter().any(|k| k == want), "{label}: no '{want}' event");
        }
        if islands > 1 {
            assert!(ks.iter().any(|k| k == "migration"), "{label}: no migration event");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn profiling_is_observational_across_schedules_and_opt_levels() {
    // Same matrix as the tracing test, for `--profile`: per-kernel
    // profiling only reads clocks and writes telemetry-only state, so
    // fronts, history, lineage and checkpoint bytes must be bit-exact
    // with it on or off. (The toy closure evaluator has no program
    // cache, so `profile` stays `None` either way — the flag pathway
    // through config, islands and checkpointing is what's pinned here;
    // tests/measured_time.rs covers a real compiled workload.)
    let (g, eval) = toy();
    let dir = tmp_dir("profbitid");
    let mut case = 0usize;
    for (opt, islands, threads, batch) in [
        (OptLevel::parse("0").unwrap(), 1usize, 1usize, 0usize),
        (OptLevel::parse("2").unwrap(), 2, 1, 32),
        (OptLevel::parse("3").unwrap(), 3, 3, 4),
    ] {
        case += 1;
        let base = SearchConfig {
            pop_size: 6,
            generations: 4,
            elites: 3,
            workers: 1,
            seed: 19,
            islands,
            migration_interval: 2,
            migrants: 1,
            island_threads: threads,
            batch,
            opt_level: opt,
            checkpoint_every: 2,
            ..Default::default()
        };
        let label = format!("opt={opt} islands={islands} threads={threads} batch={batch}");
        let ck_off = dir.join(format!("off_{case}.json"));
        let ck_on = dir.join(format!("on_{case}.json"));
        let off = run_with_checkpoint(&g, &eval, &base, Some(&ck_off));
        let on = run_with_checkpoint(
            &g,
            &eval,
            &SearchConfig { profile: true, ..base.clone() },
            Some(&ck_on),
        );
        assert_same_outcome(&off, &on, &label);
        assert!(off.profile.is_none() && on.profile.is_none(), "{label}: no program cache");
        let a = std::fs::read(&ck_off).unwrap();
        let b = std::fs::read(&ck_on).unwrap();
        assert_eq!(a, b, "{label}: checkpoint bytes diverged under profiling");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn kill_resume_trace_is_well_formed_and_outcome_identical() {
    let (g, eval) = toy();
    let dir = tmp_dir("resume");
    let ck = dir.join("ck.json");
    let trace = dir.join("trace.jsonl");
    let cfg = SearchConfig {
        pop_size: 6,
        generations: 5,
        elites: 3,
        workers: 1,
        seed: 29,
        islands: 3,
        migration_interval: 2,
        migrants: 1,
        island_threads: 3,
        checkpoint_every: 2,
        trace: Some(trace.clone()),
        ..Default::default()
    };
    let uninterrupted =
        run_with_checkpoint(&g, &eval, &SearchConfig { trace: None, ..cfg.clone() }, None);

    // "kill" after three generations, then resume to the full target;
    // both stages append to the same trace file.
    let partial_cfg = SearchConfig { generations: 3, ..cfg.clone() };
    let _partial = run_with_checkpoint(&g, &eval, &partial_cfg, Some(&ck));
    let resumed = run_with_checkpoint(&g, &eval, &cfg, Some(&ck));
    assert_same_outcome(&uninterrupted, &resumed, "traced resume");
    assert!(
        uninterrupted.pareto_lineage.iter().all(|l| l.is_some()),
        "every front point must carry lineage"
    );

    let events = parse_trace(&trace);
    let ks = kinds(&events);
    assert_eq!(ks.iter().filter(|k| *k == "run_start").count(), 1, "one cold start");
    assert_eq!(ks.iter().filter(|k| *k == "resume").count(), 1, "one resume");
    assert_eq!(ks.iter().filter(|k| *k == "run_end").count(), 2, "both stages ended");
    let resume = events.iter().find(|e| e.get("kind").unwrap().as_str().unwrap() == "resume");
    let completed = resume.unwrap().get("completed").unwrap().as_usize().unwrap();
    assert!(completed > 0, "resume event must report completed generations");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lineage_survives_checkpoint_roundtrip() {
    let (g, eval) = toy();
    let dir = tmp_dir("lineage");
    let ck = dir.join("ck.json");
    let cfg = SearchConfig {
        pop_size: 6,
        generations: 4,
        elites: 3,
        workers: 1,
        seed: 13,
        islands: 2,
        migration_interval: 1,
        migrants: 1,
        checkpoint_every: 1,
        ..Default::default()
    };
    let uninterrupted = run_with_checkpoint(&g, &eval, &cfg, None);
    let partial_cfg = SearchConfig { generations: 2, ..cfg.clone() };
    let _partial = run_with_checkpoint(&g, &eval, &partial_cfg, Some(&ck));
    let resumed = run_with_checkpoint(&g, &eval, &cfg, Some(&ck));
    assert_eq!(
        uninterrupted.pareto_lineage, resumed.pareto_lineage,
        "pareto lineage must be resume-exact"
    );
    assert!(uninterrupted.pareto_lineage.iter().all(|l| l.is_some()));
    assert_eq!(uninterrupted.pareto_lineage.len(), uninterrupted.pareto.len());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unwritable_trace_path_fails_fast() {
    let (g, eval) = toy();
    let cfg = SearchConfig {
        pop_size: 4,
        generations: 1,
        elites: 2,
        workers: 1,
        seed: 7,
        trace: Some(PathBuf::from("/nonexistent-gevo-dir/trace.jsonl")),
        ..Default::default()
    };
    let err = try_run_with_checkpoint(&g, &eval, &cfg, None);
    assert!(err.is_err(), "a bogus --trace path must error before searching");
    let msg = format!("{}", err.err().unwrap());
    assert!(msg.contains("trace"), "error should name the trace stream: {msg}");
}
