//! Integration: the pluggable operator API and its adaptive scheduler
//! over the full search stack — default-config bit-identity with the
//! pre-redesign path, determinism under fixed seeds, operator-weight
//! checkpoint roundtrip, legacy-checkpoint resume, the opt-aware neutral
//! filter, and attribution-guided (`--reseed-minimized`) runs.

use gevo_ml::coordinator::{self, ExperimentConfig, WorkloadKind};
use gevo_ml::evo::island::run_with_checkpoint;
use gevo_ml::evo::nsga2::Objectives;
use gevo_ml::evo::operators;
use gevo_ml::evo::search::{Evaluator, SearchConfig, SearchResult};
use gevo_ml::ir::op::{OpKind, ReduceKind};
use gevo_ml::ir::types::TType;
use gevo_ml::ir::Graph;
use gevo_ml::util::json::Json;

/// The toy workload shared with the island tests: runtime = normalized
/// FLOPs, error = |output − baseline| on one input.
fn toy() -> (Graph, impl Evaluator) {
    let mut g = Graph::new("toy");
    let x = g.param(TType::of(&[4, 4]));
    let e1 = g.push(OpKind::Exponential, &[x]).unwrap();
    let t = g.push(OpKind::Tanh, &[e1]).unwrap();
    let a = g.push(OpKind::Add, &[t, x]).unwrap();
    let r = g
        .push(OpKind::Reduce { dims: vec![0, 1], kind: ReduceKind::Sum }, &[a])
        .unwrap();
    g.set_outputs(&[r]);
    let base_flops = g.total_flops() as f64;
    let input = gevo_ml::tensor::Tensor::iota(&[4, 4]);
    let baseline = gevo_ml::interp::eval(&g, &[input.clone()]).unwrap()[0].item() as f64;
    let eval = move |vg: &Graph| -> Option<Objectives> {
        let out = gevo_ml::interp::eval(vg, &[input.clone()]).ok()?;
        if out[0].has_non_finite() {
            return None;
        }
        let err = (out[0].item() as f64 - baseline).abs() / baseline.abs().max(1e-9);
        let time = vg.total_flops() as f64 / base_flops;
        Some((time, err))
    };
    (g, eval)
}

fn front_of(r: &SearchResult) -> Vec<Objectives> {
    r.pareto.iter().map(|(_, o)| *o).collect()
}

struct TempCk(std::path::PathBuf);

impl TempCk {
    fn new(tag: &str) -> TempCk {
        TempCk(std::env::temp_dir().join(format!("gevo_ops_{tag}_{}.json", std::process::id())))
    }
}

impl Drop for TempCk {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

#[test]
fn explicit_default_operators_match_the_implicit_default() {
    // `--operators copy,delete` (and its alias spelling) is the same
    // stochastic process as not passing the flag at all.
    let (g, eval) = toy();
    let base = SearchConfig {
        pop_size: 8,
        generations: 3,
        elites: 4,
        workers: 1,
        seed: 17,
        ..Default::default()
    };
    let explicit = SearchConfig {
        operators: vec!["copy".into(), "delete".into()],
        ..base.clone()
    };
    let aliased = SearchConfig {
        operators: vec!["insert".into(), "delete".into()],
        ..base.clone()
    };
    let a = run_with_checkpoint(&g, &eval, &base, None);
    let b = run_with_checkpoint(&g, &eval, &explicit, None);
    let c = run_with_checkpoint(&g, &eval, &aliased, None);
    assert_eq!(front_of(&a), front_of(&b));
    assert_eq!(front_of(&a), front_of(&c));
    assert_eq!(a.total_evaluations, b.total_evaluations);
    assert_eq!(a.total_evaluations, c.total_evaluations);
}

#[test]
fn full_operator_set_searches_deterministically() {
    let (g, eval) = toy();
    let cfg = SearchConfig {
        pop_size: 10,
        generations: 4,
        elites: 4,
        workers: 2,
        seed: 29,
        operators: vec![
            "copy".into(),
            "delete".into(),
            "swap".into(),
            "replace".into(),
            "perturb".into(),
        ],
        ..Default::default()
    };
    let a = run_with_checkpoint(&g, &eval, &cfg, None);
    let b = run_with_checkpoint(&g, &eval, &cfg, None);
    assert!(!a.pareto.is_empty());
    assert_eq!(front_of(&a), front_of(&b), "same seed must reproduce the front");
    assert_eq!(a.total_evaluations, b.total_evaluations);
    // the report rows cover every operator + crossover, and proposals
    // landed across the set
    assert_eq!(a.operators.len(), 6);
    assert_eq!(a.operators.last().unwrap().name, "crossover");
    let total_props: usize = a.operators.iter().map(|o| o.proposals).sum();
    assert!(total_props > 0);
    for o in &a.operators {
        assert!(o.accepts <= o.proposals, "{}: accepts > proposals", o.name);
        assert!(o.non_neutral <= o.evals, "{}: non-neutral > evals", o.name);
    }
    // and the two runs agree on the accounting, not just the front
    for (x, y) in a.operators.iter().zip(b.operators.iter()) {
        assert_eq!(x, y, "operator stats must be deterministic");
    }
}

#[test]
fn adaptive_runs_are_deterministic_and_weights_move() {
    let (g, eval) = toy();
    let cfg = SearchConfig {
        pop_size: 10,
        generations: 5,
        elites: 4,
        workers: 2,
        seed: 31,
        adapt: true,
        operators: vec![
            "copy".into(),
            "delete".into(),
            "swap".into(),
            "perturb".into(),
        ],
        ..Default::default()
    };
    let a = run_with_checkpoint(&g, &eval, &cfg, None);
    let b = run_with_checkpoint(&g, &eval, &cfg, None);
    assert_eq!(front_of(&a), front_of(&b));
    for (x, y) in a.operators.iter().zip(b.operators.iter()) {
        assert_eq!(x.weight.map(f64::to_bits), y.weight.map(f64::to_bits));
    }
    let moved = a
        .operators
        .iter()
        .filter_map(|o| o.weight)
        .any(|w| (w - 1.0).abs() > 1e-12);
    assert!(moved, "five adaptive generations should move some weight off uniform");
}

#[test]
fn adaptive_checkpoint_resume_is_bit_identical() {
    // Kill an adaptive run after 2 of 5 generations and resume: front,
    // history, evaluation counts and final operator weights must equal
    // the uninterrupted run's — the weights are part of the state.
    let (g, eval) = toy();
    let cfg = SearchConfig {
        pop_size: 8,
        generations: 5,
        elites: 3,
        workers: 1,
        seed: 37,
        islands: 2,
        migration_interval: 2,
        migrants: 1,
        adapt: true,
        operators: vec!["copy".into(), "delete".into(), "swap".into()],
        ..Default::default()
    };
    let uninterrupted = run_with_checkpoint(&g, &eval, &cfg, None);
    let ck = TempCk::new("adapt_resume");
    let partial_cfg = SearchConfig { generations: 2, ..cfg.clone() };
    let _ = run_with_checkpoint(&g, &eval, &partial_cfg, Some(&ck.0));
    let resumed = run_with_checkpoint(&g, &eval, &cfg, Some(&ck.0));
    assert_eq!(front_of(&uninterrupted), front_of(&resumed));
    assert_eq!(uninterrupted.total_evaluations, resumed.total_evaluations);
    assert_eq!(uninterrupted.history.len(), resumed.history.len());
    assert_eq!(uninterrupted.operators.len(), resumed.operators.len());
    for (x, y) in uninterrupted.operators.iter().zip(resumed.operators.iter()) {
        assert_eq!(x.name, y.name);
        assert_eq!(
            x.weight.map(f64::to_bits),
            y.weight.map(f64::to_bits),
            "{}: resumed weights must be bit-identical",
            x.name
        );
        assert_eq!((x.proposals, x.accepts, x.evals), (y.proposals, y.accepts, y.evals));
        assert_eq!((x.non_neutral, x.inserts), (y.non_neutral, y.inserts));
    }
}

#[test]
fn reseed_minimized_checkpoint_resume_is_bit_identical() {
    // The attribution-guided mode adds hint state to the checkpoint; a
    // resumed run must replay migrations (and their minimizations)
    // identically.
    let (g, eval) = toy();
    let cfg = SearchConfig {
        pop_size: 8,
        generations: 4,
        elites: 3,
        workers: 1,
        seed: 41,
        islands: 2,
        migration_interval: 1,
        migrants: 2,
        init_mutations: 4,
        reseed_minimized: true,
        ..Default::default()
    };
    let uninterrupted = run_with_checkpoint(&g, &eval, &cfg, None);
    let ck = TempCk::new("rsm_resume");
    let partial_cfg = SearchConfig { generations: 2, ..cfg.clone() };
    let _ = run_with_checkpoint(&g, &eval, &partial_cfg, Some(&ck.0));
    let resumed = run_with_checkpoint(&g, &eval, &cfg, Some(&ck.0));
    assert_eq!(front_of(&uninterrupted), front_of(&resumed));
    assert_eq!(uninterrupted.total_evaluations, resumed.total_evaluations);
    assert_eq!(uninterrupted.migrations, resumed.migrations);
}

#[test]
fn resuming_with_different_operator_config_is_rejected() {
    let (g, eval) = toy();
    let cfg = SearchConfig {
        pop_size: 6,
        generations: 2,
        elites: 3,
        workers: 1,
        seed: 43,
        ..Default::default()
    };
    let ck = TempCk::new("op_mismatch");
    let _ = run_with_checkpoint(&g, &eval, &cfg, Some(&ck.0));
    let text = std::fs::read_to_string(&ck.0).unwrap();
    let j = Json::parse(&text).unwrap();
    // the echo carries the canonical operator config
    let echo = j.get("config").unwrap();
    assert_eq!(echo.get("operators").unwrap().as_str().unwrap(), "copy,delete");
    assert!(!echo.get("adapt").unwrap().as_bool().unwrap());
    for other in [
        SearchConfig { adapt: true, ..cfg.clone() },
        SearchConfig { filter_neutral: true, ..cfg.clone() },
        SearchConfig { operators: vec!["delete".into()], ..cfg.clone() },
    ] {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_with_checkpoint(&g, &eval, &other, Some(&ck.0))
        }));
        assert!(result.is_err(), "mismatched operator config must be refused");
    }
}

#[test]
fn filter_neutral_experiment_reports_filtered_proposals() {
    // End-to-end through a real workload (TrainingWorkload exposes its
    // ProgramCache): the filtered_neutral counter surfaces in the result
    // and the search still produces a valid front.
    let run_at = |filter: bool| {
        let cfg = ExperimentConfig {
            kind: WorkloadKind::TwoFcTraining,
            search: SearchConfig {
                pop_size: 8,
                generations: 3,
                elites: 3,
                workers: 2,
                seed: 47,
                opt_level: gevo_ml::opt::OptLevel::O2,
                filter_neutral: filter,
                ..Default::default()
            },
            fit_samples: 64,
            test_samples: 32,
            epochs: 1,
            ..Default::default()
        };
        coordinator::run_experiment(&cfg)
    };
    let plain = run_at(false);
    let filtered = run_at(true);
    assert!(!filtered.front.is_empty());
    // The counter surfaces through the workload's cache (whether this
    // exact seed window trips it is chance; the guaranteed >0 case lives
    // in evo::operators' unit tests against a dead-op graph).
    let stats = filtered.search.program_opt.expect("O2 workload reports opt stats");
    assert!(stats.memo_misses > 0, "the filter's key probes must reach the memo");
    let plain_stats = plain.search.program_opt.expect("opt stats present");
    assert_eq!(plain_stats.filtered_neutral, 0, "filter off must count nothing");
    // determinism with the filter on
    let again = run_at(true);
    let fa: Vec<_> = filtered.front.iter().map(|p| p.fit).collect();
    let fb: Vec<_> = again.front.iter().map(|p| p.fit).collect();
    assert_eq!(fa, fb);
    assert_eq!(
        filtered.search.program_opt.unwrap().filtered_neutral,
        again.search.program_opt.unwrap().filtered_neutral
    );
}

#[test]
fn reseed_minimized_experiment_runs_end_to_end() {
    let cfg = ExperimentConfig {
        kind: WorkloadKind::TwoFcTraining,
        search: SearchConfig {
            pop_size: 6,
            generations: 3,
            elites: 3,
            workers: 2,
            seed: 53,
            islands: 2,
            migration_interval: 1,
            migrants: 2,
            reseed_minimized: true,
            ..Default::default()
        },
        fit_samples: 64,
        test_samples: 32,
        epochs: 1,
        ..Default::default()
    };
    let a = coordinator::run_experiment(&cfg);
    let b = coordinator::run_experiment(&cfg);
    assert!(!a.front.is_empty());
    assert!(a.search.migrations > 0, "two islands at interval 1 must migrate");
    let fa: Vec<_> = a.front.iter().map(|p| p.fit).collect();
    let fb: Vec<_> = b.front.iter().map(|p| p.fit).collect();
    assert_eq!(fa, fb, "attribution-guided runs must stay seed-deterministic");
}

#[test]
fn unknown_operator_names_error_with_the_known_list() {
    let err = operators::canonicalize_names(&["copy", "mutate-harder"]).unwrap_err();
    assert!(err.contains("unknown operator 'mutate-harder'"), "{err}");
    for (name, _, _) in operators::registry() {
        assert!(err.contains(name), "error must list known operator {name}: {err}");
    }
}
